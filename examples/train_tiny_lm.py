"""End-to-end driver: train a ~100M-param LM with Hyft softmax for a few
hundred steps on synthetic data, with checkpointing + restart.

By default runs a truly-CPU-sized model for a smoke pass; pass --full for
the ~100M configuration (slow on 1 CPU core but functional).

Run:  PYTHONPATH=src python examples/train_tiny_lm.py [--full] [--steps N]
"""
import argparse

import jax

from repro import optim
from repro.configs.base import ModelConfig, TrainConfig
from repro.data.synthetic import DataConfig, lm_batch
from repro.models import build_model
from repro.train.loop import run_train
from repro.train.state import init_state
from repro.train.step import make_step_fn

ap = argparse.ArgumentParser()
ap.add_argument("--full", action="store_true", help="~100M params")
ap.add_argument("--steps", type=int, default=None)
ap.add_argument("--ckpt-dir", default="/tmp/hyft_tiny_lm")
args = ap.parse_args()

if args.full:  # ~100M params: 12L x 768 with a 32k vocab
    cfg = ModelConfig(name="tiny-100m", family="dense", n_layers=12,
                      d_model=768, n_heads=12, n_kv_heads=12, d_head=64,
                      d_ff=3072, vocab=32768, softmax_impl="hyft16",
                      tie_embeddings=True, compute_dtype="float32")
    steps, batch, seq = args.steps or 200, 8, 256
else:
    cfg = ModelConfig(name="tiny-2m", family="dense", n_layers=4,
                      d_model=128, n_heads=4, n_kv_heads=4, d_head=32,
                      d_ff=512, vocab=512, softmax_impl="hyft16",
                      tie_embeddings=True, compute_dtype="float32")
    steps, batch, seq = args.steps or 300, 16, 64

model = build_model(cfg)
n = sum(x.size for x in jax.tree.leaves(
    jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))))
print(f"{cfg.name}: {n/1e6:.1f}M params, softmax={cfg.softmax_impl}")

tcfg = TrainConfig(total_steps=steps, lr=3e-3, warmup_steps=20,
                   checkpoint_every=50, z_loss=0.0)
ocfg = optim.OptConfig(name="adamw", lr=3e-3)
dcfg = DataConfig(vocab=cfg.vocab, seq_len=seq, global_batch=batch)

state = init_state(model, ocfg, jax.random.PRNGKey(0))
step = jax.jit(make_step_fn(model, tcfg, ocfg), donate_argnums=(0,))
state, hist = run_train(state, step, lambda s: lm_batch(dcfg, s), tcfg,
                        ckpt_dir=args.ckpt_dir, log_every=10)
print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
      f"({'PASS' if hist[-1]['loss'] < hist[0]['loss'] else 'FAIL'})")
