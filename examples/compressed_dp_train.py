"""Distributed-optimization demo: int8 stochastic-rounding gradient
all-reduce inside a shard_map data-parallel training step.

On a 1000+-node fleet the cross-pod DP gradient reduce is the dominant
inter-pod collective; quantizing the payload to int8 cuts that roofline
term ~4x (fp32 grads).  This example trains the same tiny LM twice — exact
fp32 psum vs int8 compressed psum — and shows the loss curves coincide
(stochastic rounding keeps the estimator unbiased).

Run:  PYTHONPATH=src python examples/compressed_dp_train.py
"""
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro import optim
from repro.distributed.compat import shard_map
from repro.configs.base import ModelConfig
from repro.data.synthetic import DataConfig, lm_batch
from repro.models import build_model
from repro.models.layers import unbox
from repro.optim.compression import compressed_psum_tree

cfg = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=4, d_head=16, d_ff=256, vocab=128,
                  softmax_impl="hyft16", tie_embeddings=True,
                  compute_dtype="float32")
model = build_model(cfg)
ocfg = optim.OptConfig(name="adamw", lr=3e-3, weight_decay=0.0)
dcfg = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8)
mesh = Mesh(np.array(jax.devices()[:1]), ("dp",))


def make_step(compress: bool):
    @partial(shard_map, mesh=mesh,
             in_specs=(P(), P(), P("dp"), P("dp"), P()),
             out_specs=(P(), P(), P()))
    def dp_step(params, opt, tokens, targets, key):
        batch = {"tokens": tokens, "targets": targets,
                 "mask": jnp.ones_like(targets, jnp.float32)}
        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, batch, remat="none", z_loss=0.0)[0])(params)
        if compress:
            grads = compressed_psum_tree(grads, "dp", key)
            n = jax.lax.psum(1, "dp")
            grads = jax.tree.map(lambda g: g / n, grads)
        else:
            grads = jax.lax.pmean(grads, "dp")
        loss = jax.lax.pmean(loss, "dp")
        new_params, new_opt = optim.update(ocfg, grads, opt, params)
        return new_params, new_opt, loss
    return jax.jit(dp_step)


for compress in (False, True):
    params = unbox(model.init(jax.random.PRNGKey(0)))
    opt = optim.init(ocfg, params)
    step = make_step(compress)
    losses = []
    for s in range(60):
        b = lm_batch(dcfg, s)
        key = jax.random.fold_in(jax.random.PRNGKey(7), s)
        params, opt, loss = step(params, opt, b["tokens"], b["targets"], key)
        losses.append(float(loss))
    label = "int8-compressed" if compress else "exact fp32     "
    print(f"{label} psum: loss {losses[0]:.3f} -> {losses[-1]:.3f}")
