"""Continuous-batching serving tour: slot pool, paged KV pages, prefix cache.

Eight ragged requests drawn from two shared system prompts go through the
continuous-batching scheduler three ways:

  dense        — the slot-pool KV cache (one max_len stripe per slot)
  paged        — fixed-size KV pages from a global pool + block tables
  paged+prefix — pages plus the radix-trie prefix cache: requests sharing
                 a cached prompt prefix reuse its pages and skip prefill
                 for the cached tokens (watch ``prefill_tokens`` drop)

Greedy outputs are token-for-token identical across all three (and to a
solo ``generate`` of each prompt) — layout and caching are invisible to
the arithmetic.  A plain lockstep ``generate`` run closes the tour.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.configs.base import ServeConfig
from repro.models import build_model
from repro.models.layers import unbox
from repro.serve.engine import generate
from repro.serve.scheduler import Request, SlotPoolEngine

cfg = smoke_config(get_config("qwen2-1.5b")).with_(softmax_impl="hyft16",
                                                   vocab=128)
model = build_model(cfg)
params = unbox(model.init(jax.random.PRNGKey(0)))

rng = np.random.default_rng(0)
systems = [rng.integers(0, cfg.vocab, 16).astype(np.int32) for _ in range(2)]
reqs = [Request(rid=i,
                tokens=np.concatenate(
                    [systems[i % 2],
                     rng.integers(0, cfg.vocab, 4).astype(np.int32)]),
                max_new=int(rng.integers(4, 9)))
        for i in range(8)]

outs = {}
for name, kw in (("dense", dict()),
                 ("paged", dict(kv_layout="paged", page_size=8)),
                 ("paged+prefix", dict(kv_layout="paged", page_size=8,
                                       prefix_cache=True))):
    scfg = ServeConfig(max_len=48, cache_dtype="float32",
                       scheduler="continuous", n_slots=4, decode_burst=4,
                       eos_id=None, **kw)
    eng = SlotPoolEngine(model, params, scfg)
    done = eng.run(reqs)
    outs[name] = {rid: c.tokens for rid, c in done.items()}
    st = eng.stats
    paged_info = (f" cached={st['cached_tokens']} hits={st['prefix_hits']}"
                  f" pages_peak={st['pages_peak']}"
                  if kw.get("kv_layout") == "paged" else "")
    print(f"{name:13s} prefill_tokens={st['prefill_tokens']:3d}"
          f" prefills={st['prefills']}{paged_info}")

assert outs["dense"] == outs["paged"] == outs["paged+prefix"]
print("all layouts emit identical greedy tokens")
for rid in sorted(outs["dense"]):
    print(f"  [{rid}] {outs['dense'][rid]}")

# lockstep rectangular generate, for contrast (one batch, one horizon)
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0,
                                      cfg.vocab, jnp.int32)}
out = generate(model, params, batch, ServeConfig(max_len=32,
                                                 cache_dtype="float32"),
               max_new=8)
print(f"lockstep generate {out.shape}: {out[0].tolist()}")
