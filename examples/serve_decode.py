"""Continuous-batching serving tour: slot pool, paged KV pages, prefix
cache, and (``--spec``) speculative decoding.

Eight ragged requests drawn from two shared system prompts go through the
continuous-batching scheduler three ways:

  dense        — the slot-pool KV cache (one max_len stripe per slot)
  paged        — fixed-size KV pages from a global pool + block tables
  paged+prefix — pages plus the radix-trie prefix cache: requests sharing
                 a cached prompt prefix reuse its pages and skip prefill
                 for the cached tokens (watch ``prefill_tokens`` drop)

Greedy outputs are token-for-token identical across all three (and to a
solo ``generate`` of each prompt) — layout and caching are invisible to
the arithmetic.  With ``--spec`` the same requests are ALSO served by the
speculative scheduler (n-gram self-drafting + one-call verify bursts,
``--draft-k`` tokens per step): still token-for-token identical, but with
an acceptance-rate summary showing how many tokens each model call earned.
A plain lockstep ``generate`` run closes the tour.

``--prefill-chunk N`` splits every prompt's prefill into N-token chunks
interleaved with decode bursts (and ``--no-pack-prefill`` feeds one prompt
at a time instead of packing prefilling slots into one call) — the outputs
still match token for token; only the latency shape changes.

Run:  PYTHONPATH=src python examples/serve_decode.py [--spec] [--draft-k 4]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.configs.base import ServeConfig
from repro.models import build_model
from repro.models.layers import unbox
from repro.serve.engine import generate
from repro.serve.scheduler import Request, SlotPoolEngine

ap = argparse.ArgumentParser()
ap.add_argument("--spec", action="store_true",
                help="also serve with the speculative scheduler and print "
                     "the acceptance-rate summary")
ap.add_argument("--draft-k", type=int, default=4,
                help="draft tokens verified per slot per spec step")
ap.add_argument("--prefill-chunk", type=int, default=0,
                help="max prompt tokens per prefill call (0 = whole prompt)")
ap.add_argument("--pack-prefill", default=True,
                action=argparse.BooleanOptionalAction,
                help="pack prefilling slots into one bucketed chunk call")
args = ap.parse_args()

cfg = smoke_config(get_config("qwen2-1.5b")).with_(softmax_impl="hyft16",
                                                   vocab=128)
model = build_model(cfg)
params = unbox(model.init(jax.random.PRNGKey(0)))

rng = np.random.default_rng(0)
systems = [rng.integers(0, cfg.vocab, 16).astype(np.int32) for _ in range(2)]
reqs = [Request(rid=i,
                tokens=np.concatenate(
                    [systems[i % 2],
                     rng.integers(0, cfg.vocab, 4).astype(np.int32)]),
                max_new=int(rng.integers(4, 9)))
        for i in range(8)]

variants = [("dense", dict()),
            ("paged", dict(kv_layout="paged", page_size=8)),
            ("paged+prefix", dict(kv_layout="paged", page_size=8,
                                  prefix_cache=True))]
if args.spec:
    variants.append(("spec", dict(scheduler="spec", draft_k=args.draft_k)))

outs = {}
for name, kw in variants:
    # audit=True: pool/trie refcounts are recomputed from first principles
    # at every admission/finish/preemption checkpoint (DESIGN.md §13)
    scfg = ServeConfig(max_len=48, cache_dtype="float32",
                       scheduler=kw.pop("scheduler", "continuous"),
                       n_slots=4, decode_burst=4, eos_id=None,
                       prefill_chunk=args.prefill_chunk,
                       pack_prefill=args.pack_prefill, audit=True, **kw)
    eng = SlotPoolEngine(model, params, scfg)
    try:
        done = eng.run(reqs)
    except KeyboardInterrupt:
        # graceful drain: unfinished requests become partial Completions
        # with cancelled=True instead of a traceback losing everything
        done = eng.shutdown()
        npart = sum(1 for c in done.values() if c.cancelled)
        print(f"\ninterrupted during {name}: {npart} request(s) drained "
              "as cancelled, partial tokens kept:")
        for rid in sorted(done):
            c = done[rid]
            print(f"  [{rid}]{' cancelled' if c.cancelled else ''} "
                  f"{c.tokens}")
        raise SystemExit(130)
    outs[name] = {rid: c.tokens for rid, c in done.items()}
    st = eng.stats
    extra = (f" cached={st['cached_tokens']} hits={st['prefix_hits']}"
             f" pages_peak={st['pages_peak']}"
             if kw.get("kv_layout") == "paged" else "")
    if name == "spec":
        acc = st["accepted_tokens"] / max(1, st["draft_tokens"])
        extra = (f" drafted={st['draft_tokens']}"
                 f" accepted={st['accepted_tokens']} (rate {acc:.2f})"
                 f" tokens/model-call="
                 f"{st['tokens_emitted'] / max(1, st['model_calls']):.2f}")
    print(f"{name:13s} prefill_tokens={st['prefill_tokens']:3d}"
          f" prefills={st['prefills']}{extra}")

names = [n for n, _ in variants]
assert all(outs[n] == outs["dense"] for n in names)
print(f"all {len(names)} serving modes emit identical greedy tokens")
for rid in sorted(outs["dense"]):
    print(f"  [{rid}] {outs['dense'][rid]}")

# lockstep rectangular generate, for contrast (one batch, one horizon)
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0,
                                      cfg.vocab, jnp.int32)}
out = generate(model, params, batch, ServeConfig(max_len=32,
                                                 cache_dtype="float32"),
               max_new=8)
print(f"lockstep generate {out.shape}: {out[0].tolist()}")
