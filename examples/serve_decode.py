"""Serve a small model with batched requests: prefill + greedy decode,
with the Hyft softmax in every attention layer and the router.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_config
from repro.configs.base import ServeConfig
from repro.models import build_model
from repro.models.layers import unbox
from repro.serve.engine import generate

for arch in ["qwen2-1.5b", "mamba2-370m", "phi3.5-moe-42b-a6.6b"]:
    cfg = smoke_config(get_config(arch)).with_(softmax_impl="hyft16")
    model = build_model(cfg)
    params = unbox(model.init(jax.random.PRNGKey(0)))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0,
                                          cfg.vocab, jnp.int32)}
    scfg = ServeConfig(max_len=32, cache_dtype="float32")
    out = generate(model, params, batch, scfg, max_new=8)
    print(f"{arch:24s} generated {out.shape}: {out[0].tolist()}")
