"""Quickstart: Hyft softmax as a drop-in, its gradient, and the kernels.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import HYFT16, HYFT32, hyft_softmax, get_softmax
from repro.kernels import ops

key = jax.random.PRNGKey(0)
z = jax.random.normal(key, (4, 64), jnp.float32) * 3.0

# 1. the accelerator emulation vs exact softmax
s_hyft = hyft_softmax(z, HYFT32)
s_ref = jax.nn.softmax(z, -1)
print("Hyft32 vs exact: mean|err| =",
      float(jnp.mean(jnp.abs(s_hyft - s_ref))))

# 2. training through the accelerator's own backward datapath
w = jax.random.normal(jax.random.PRNGKey(1), (64,))
g = jax.grad(lambda x: jnp.sum(hyft_softmax(x, HYFT32) * w))(z)
print("hyft-grad norm:", float(jnp.linalg.norm(g)))

# 3. the Pallas kernel (interpret mode on CPU, compiled on TPU)
s_kernel = ops.hyft_softmax(z, HYFT16)
print("kernel == emulation:",
      bool(jnp.all(s_kernel == hyft_softmax(z, HYFT16))))

# 4. every registry implementation on one row
for name in ["exact", "hyft16", "hyft32", "base2", "koca"]:
    s = get_softmax(name)(z[:1]).astype(jnp.float32)
    print(f"{name:8s} first-row max prob = {float(s.max()):.4f} "
          f"sum = {float(s.sum()):.4f}")

# 5. fused flash attention with Hyft numerics
q = jax.random.normal(key, (1, 4, 128, 32))
k = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 128, 32))
v = jax.random.normal(jax.random.PRNGKey(3), (1, 2, 128, 32))
o = ops.hyft_attention(q, k, v, HYFT32, causal=True)
print("flash-hyft attention out:", o.shape, o.dtype)
