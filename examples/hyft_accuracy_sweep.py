"""Sweep the accelerator's reconfigurable knobs (paper §3.1/§3.3):
Precision (frac_bits), adder-tree width (acc_bits), STEP, and io format —
the accuracy/hardware trade-off surface.

Run:  PYTHONPATH=src python examples/hyft_accuracy_sweep.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.core.hyft import HYFT16, HYFT32, hyft_softmax_fwd
from repro.core.costmodel import hyft_cost

key = jax.random.PRNGKey(0)
z = jax.random.normal(key, (256, 128), jnp.float32) * 3.0
ref = jax.nn.softmax(z, -1)


def err(cfg):
    s = hyft_softmax_fwd(z, cfg).astype(jnp.float32)
    return float(jnp.mean(jnp.abs(s - ref)))


print("== Precision (frac_bits) sweep, Hyft32 base ==")
for f in (8, 10, 12, 16, 20):
    cfg = dataclasses.replace(HYFT32, frac_bits=f, mant_bits=min(f, 16),
                              acc_bits=min(f + 4, 22))
    print(f"frac_bits={f:2d}  mean|err|={err(cfg):.5f}")

print("== adder-tree acc_bits sweep ==")
for a in (8, 10, 14, 20):
    cfg = dataclasses.replace(HYFT32, acc_bits=a)
    print(f"acc_bits={a:2d}   mean|err|={err(cfg):.5f}")

print("== STEP sweep (max-search stride) with hardware cost ==")
for s in (1, 2, 4, 8):
    cfg = dataclasses.replace(HYFT16, step=s)
    c = hyft_cost(N=8, W=16, step=s)
    print(f"step={s}  mean|err|={err(cfg):.5f}  stage1_delay={c.stage_delays[0]:.2f}")

print("== io formats ==")
for cfg, name in ((HYFT16, "hyft16"), (HYFT32, "hyft32"),
                  (dataclasses.replace(HYFT16, io_dtype="bfloat16"), "hyft16b")):
    print(f"{name}: mean|err|={err(cfg):.5f}")
