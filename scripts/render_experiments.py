"""Render the §Dry-run and §Roofline tables of EXPERIMENTS.md from cached
dry-run JSONs. Prints markdown to stdout."""
import glob, json, os, sys

RES = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")

def load(mesh, tag="baseline"):
    out = {}
    for f in sorted(glob.glob(os.path.join(RES, f"{mesh}__*__{tag}.json"))):
        r = json.load(open(f))
        out[(r["arch"], r["shape"])] = r
    return out

def fmt_bytes(b):
    return f"{b/2**30:.2f}"

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

def dryrun_table(mesh):
    cells = load(mesh)
    print(f"\n### Mesh: {mesh} ({'2x16x16=512' if mesh=='multi' else '16x16=256'} chips)\n")
    print("| arch | shape | status | compile s | arg GiB/dev | temp GiB/dev | peak GiB/dev | coll bytes/dev | dominant coll |")
    print("|---|---|---|---|---|---|---|---|---|")
    for (arch, shape), r in sorted(cells.items(), key=lambda kv: (kv[0][0], SHAPE_ORDER.index(kv[0][1]))):
        if r["status"] == "skipped":
            print(f"| {arch} | {shape} | SKIP (full-attention, sub-quadratic required) | | | | | | |")
            continue
        if r["status"] != "ok":
            print(f"| {arch} | {shape} | ERROR | | | | | | |")
            continue
        m, ro = r["memory"], r["roofline"]
        bd = ro["coll_breakdown"]
        dom_coll = max(bd, key=bd.get) if bd else "-"
        print(f"| {arch} | {shape} | ok | {r['compile_s']} | {fmt_bytes(m['argument_bytes'])} | "
              f"{fmt_bytes(m['temp_bytes'])} | {fmt_bytes(m['peak_device_bytes'])} | "
              f"{ro['coll_bytes_device']/2**20:.0f} MiB | {dom_coll} |")

def roofline_table(mesh):
    cells = load(mesh)
    print(f"\n### Roofline — {mesh} pod\n")
    print("| arch | shape | compute s | memory s | collective s | dominant | frac | MODEL_FLOPS | useful ratio | one-line bottleneck note |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for (arch, shape), r in sorted(cells.items(), key=lambda kv: (kv[0][0], SHAPE_ORDER.index(kv[0][1]))):
        if r["status"] != "ok":
            continue
        ro = r["roofline"]
        note = {
            "train_4k": "unfused attention score traffic + optimizer streams",
            "prefill_32k": "attention score materialization at 32k",
            "decode_32k": "weight+KV streaming (bandwidth-bound by nature)",
            "long_500k": "state/cache streaming",
        }[shape]
        print(f"| {arch} | {shape} | {ro['compute_s']:.3e} | {ro['memory_s']:.3e} | "
              f"{ro['collective_s']:.3e} | {ro['dominant']} | {ro['roofline_fraction']:.3f} | "
              f"{r['model_flops']:.2e} | {r['useful_flops_ratio']:.3f} | {note} |")

if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        dryrun_table("single"); dryrun_table("multi")
    if which in ("all", "roofline"):
        roofline_table("single")
