"""Post-process cached dry-run JSONs: apply the scan trip-count correction
(analysis.scan_trip_factor) to cells written before the fix. Idempotent:
cells already carrying a matching trip_factor are left untouched."""
import glob, json, os, sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.configs import SHAPES, get_config
from repro.roofline import analysis, hw

RES = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")

for f in sorted(glob.glob(os.path.join(RES, "*.json"))):
    r = json.load(open(f))
    if r.get("status") != "ok":
        continue
    cfg = get_config(r["arch"])
    shape = SHAPES[r["shape"]]
    tf = analysis.scan_trip_factor(cfg, r["kind"], shape.seq, shape.batch,
                                   r.get("microbatch", 0) or 0)
    if abs(r.get("trip_factor", 1.0) - tf) < 1e-9 and "trip_factor" in r:
        continue
    old = r["roofline"]
    prev_tf = r.get("trip_factor", 1.0)
    chips = old["chips"]
    flops_dev = old["hlo_flops_global"] / chips / prev_tf * tf
    bytes_dev = old["hlo_bytes_global"] / chips / prev_tf * tf
    coll_dev = old["coll_bytes_device"] / prev_tf * tf
    roof = analysis.Roofline(
        compute_s=flops_dev / hw.PEAK_FLOPS_BF16,
        memory_s=bytes_dev / hw.HBM_BW,
        collective_s=coll_dev / hw.ICI_BW,
        hlo_flops_global=flops_dev * chips,
        hlo_bytes_global=bytes_dev * chips,
        coll_bytes_device=coll_dev,
        coll_breakdown=old["coll_breakdown"],
        chips=chips)
    r["trip_factor"] = tf
    r["roofline"] = roof.to_dict()
    r["useful_flops_ratio"] = (r["model_flops"] / roof.hlo_flops_global
                               if roof.hlo_flops_global else 0.0)
    json.dump(r, open(f, "w"), indent=1, default=str)
    print(f"fixed {os.path.basename(f)} tf={tf:.0f} "
          f"dom={roof.dominant} frac={roof.roofline_fraction:.3f} "
          f"useful={r['useful_flops_ratio']:.3f}")
