"""Static analysis driver (DESIGN.md #14): run the four invariant passes
and exit non-zero on any finding.

    PYTHONPATH=src python scripts/check.py --all [--verbose]
    PYTHONPATH=src python scripts/check.py --lint --pallas

Passes:
  --jaxpr    format-flow audit of the real serving/training executables
  --pallas   BlockSpec tile bounds / divisibility / ref-dtype check over
             the kernel registry
  --retrace  steady-state serving (warm buckets, 8 admissions) compiles
             nothing new, for the continuous and spec schedulers
  --lint     AST rules over src/repro and scripts/ (traced-bool, host-call,
             prng.constant-seed, cache.not-donated, obs.untimed-hot-path)
  --bench-regress
             compare the repo's BENCH_*.json artifacts against their
             BENCH_ledger.jsonl baseline rows with per-metric tolerances
             (opt-in: not part of --all — it needs bench artifacts, which
             only bench runs produce)

``--verbose`` also prints the scalar weak-convert churn tally from the
jaxpr pass (notes, not findings: XLA folds rank-0 weak casts).
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--jaxpr", action="store_true")
    ap.add_argument("--pallas", action="store_true")
    ap.add_argument("--retrace", action="store_true")
    ap.add_argument("--lint", action="store_true")
    ap.add_argument("--bench-regress", action="store_true",
                    help="BENCH_*.json vs ledger baseline (not in --all)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)
    explicit = (args.jaxpr or args.pallas or args.retrace or args.lint
                or args.bench_regress)
    if args.all or not explicit:
        args.jaxpr = args.pallas = args.retrace = args.lint = True

    # lint is pure AST -- run it first so syntax-level breakage is reported
    # even when tracing-based passes cannot build the executables
    passes = []
    if args.lint:
        from repro.analysis import lint
        passes.append(("lint", lambda: lint.run()))
    if args.jaxpr:
        from repro.analysis import jaxpr_audit
        stats: dict = {}
        passes.append(("jaxpr", lambda: jaxpr_audit.run(stats=stats)))
    else:
        stats = {}
    if args.pallas:
        from repro.analysis import pallas_check
        passes.append(("pallas", lambda: pallas_check.run()))
    if args.retrace:
        from repro.analysis import retrace
        passes.append(("retrace", lambda: retrace.run()))
    if args.bench_regress:
        from repro.obs import ledger
        root = os.path.join(os.path.dirname(__file__), "..")
        passes.append(("bench", lambda: ledger.regress(root)))

    total = 0
    for name, fn in passes:
        t0 = time.time()
        findings = fn()
        dt = time.time() - t0
        status = "clean" if not findings else f"{len(findings)} finding(s)"
        print(f"[check] {name:8s} {status} ({dt:.1f}s)")
        for f in findings:
            print(f"  {f}")
        total += len(findings)
    if args.verbose and stats:
        print(f"[check] notes: {stats.get('scalar_weak_converts', 0)} scalar "
              f"weak-typed converts (rank-0, folded by XLA; churn only)")
    if total:
        print(f"[check] FAILED: {total} finding(s)")
        return 1
    print("[check] all passes clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
