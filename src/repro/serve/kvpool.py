"""Paged KV-cache subsystem: page allocator + radix-trie prefix cache.

The slot-pool scheduler (PR 3) gives every slot a dense ``(max_len,)`` KV
stripe: memory scales with the worst case and identical prompt prefixes are
recomputed per request.  This module supplies the two host-side pieces of
the paged layout (DESIGN.md §10):

  PagePool   — a fixed pool of ``page_size``-token KV pages with refcounts
               and a free list.  Page id 0 is RESERVED as the null page: the
               device-side write path redirects masked (inactive-row) cache
               writes at it, so a scatter never needs a gather-then-rewrite
               to express "no write".  Usable ids are 1..n_pages.
  RadixTrie  — a page-granular radix trie over prompt token sequences.
               Edges hold page-aligned token runs (children are keyed by
               their first page of tokens, so two edges under one node can
               never share a first page and splits always happen on page
               boundaries).  Matching returns whole shared pages only —
               sharing is copy-on-write by construction: a request's first
               divergent token lands in a freshly allocated page, so shared
               pages are read-only for their whole lifetime and "divergence"
               never copies anything.

Refcount discipline: a page's count = (#slots whose block table maps it)
+ (1 if a trie node references it).  ``RadixTrie.insert`` adopts only the
pages the trie did not already know (existing nodes win — a concurrent
identical prompt keeps the first writer's pages and the duplicate copies
are freed when their slot finishes).  Eviction walks LRU leaves whose pages
are trie-only (refcount 1) and frees whole edges; removing a leaf can
expose its parent, so the walk re-collects until the demand is met.

The device-side halves — page pools as cache leaves, block-table decode
kernels, the scatter that redirects masked writes to page 0 — live in
``repro.models.attention`` and ``repro.kernels.flash_attention``.
"""
from __future__ import annotations

from typing import Iterable, Optional

import numpy as np

NULL_PAGE = 0  # reserved sink page: masked writes land here, never read


class AuditError(AssertionError):
    """A pool/trie invariant failed an ``audit()`` recomputation.

    Raised instead of silently serving from corrupt bookkeeping: a wrong
    refcount either leaks pages (capacity slowly vanishes) or double-frees
    them (two requests share one physical page and corrupt each other) —
    the serving-robustness contract (DESIGN.md §13) is that the scheduler
    surfaces this immediately at the checkpoint that created it.
    """


class PagePool:
    """Refcounted allocator over page ids 1..n_pages (0 is the null page)."""

    def __init__(self, n_pages: int):
        if n_pages < 1:
            raise ValueError("PagePool needs at least one usable page")
        self.n_pages = n_pages
        self.refs = np.zeros(n_pages + 1, np.int32)
        self.refs[NULL_PAGE] = 1          # never allocated, never freed
        self._free = list(range(n_pages, 0, -1))  # pop() hands out low ids

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.n_pages - len(self._free)

    def alloc(self, n: int) -> Optional[list]:
        """``n`` fresh pages at refcount 1, or None (caller evicts/preempts);
        never a partial allocation."""
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self.refs[p] = 1
        return pages

    def incref(self, page: int) -> None:
        assert self.refs[page] > 0, f"incref of free page {page}"
        self.refs[page] += 1

    def decref(self, page: int) -> None:
        assert page != NULL_PAGE and self.refs[page] > 0
        self.refs[page] -= 1
        if self.refs[page] == 0:
            self._free.append(page)

    def audit(self, holders: Iterable[list],
              trie: Optional["RadixTrie"] = None) -> None:
        """Recompute every refcount from first principles and cross-check.

        ``holders``: the live slots' page lists (a slot's block table must
        never alias one physical page at two virtual blocks).  ``trie``:
        the prefix cache, if any — each page a trie node references holds
        exactly one trie count.  Verifies, raising :class:`AuditError`:

          * recomputed count == stored ``refs`` for every page;
          * the null page is never held, never freed, never allocated;
          * the free list has no duplicates (double-free), holds exactly
            the refcount-0 pages, and is disjoint from every holder.
        """
        expected = np.zeros(self.n_pages + 1, np.int64)
        expected[NULL_PAGE] = 1
        for i, pages in enumerate(holders):
            if len(pages) != len(set(pages)):
                raise AuditError(f"holder {i} aliases a page twice: {pages}")
            for p in pages:
                if p == NULL_PAGE:
                    raise AuditError(f"holder {i} holds the null page")
                if not 0 < p <= self.n_pages:
                    raise AuditError(f"holder {i} holds out-of-range {p}")
                expected[p] += 1
        if trie is not None:
            for p in trie.audit():
                expected[p] += 1
        stored = self.refs.astype(np.int64)
        if not np.array_equal(stored, expected):
            bad = np.nonzero(stored != expected)[0]
            raise AuditError(
                f"refcount drift at pages {bad.tolist()}: "
                f"stored {stored[bad].tolist()} != "
                f"recomputed {expected[bad].tolist()}")
        free = self._free
        if len(free) != len(set(free)):
            raise AuditError("free list holds a page twice (double-free)")
        if NULL_PAGE in free:
            raise AuditError("null page on the free list")
        want_free = {int(p) for p in np.nonzero(expected == 0)[0] if p}
        if set(free) != want_free:
            raise AuditError(
                f"free list {sorted(set(free))} != refcount-0 pages "
                f"{sorted(want_free)}")


class _Node:
    __slots__ = ("tokens", "pages", "children", "parent", "t")

    def __init__(self, tokens, pages, parent):
        self.tokens = tuple(tokens)   # edge label, len == len(pages) * ps
        self.pages = list(pages)
        self.children: dict = {}      # first-page token tuple -> _Node
        self.parent = parent
        self.t = 0                    # LRU clock of the last touch


class RadixTrie:
    """Page-granular radix trie mapping prompt prefixes to KV pages.

    The trie holds one refcount on every page it references; ``match``
    returns pages WITHOUT increfing them — the caller takes its own
    reference before anything that could trigger eviction.
    """

    def __init__(self, pool: PagePool, page_size: int):
        assert page_size >= 1
        self.pool = pool
        self.ps = page_size
        self.root = _Node((), [], None)
        self._clock = 0

    # -- internals -----------------------------------------------------

    def _page(self, tokens, i) -> tuple:
        return tuple(int(t) for t in tokens[i * self.ps:(i + 1) * self.ps])

    def _common_pages(self, node: _Node, tokens, i, n) -> int:
        """Leading pages of ``node``'s edge equal to tokens[i*ps:...]."""
        c = 0
        while (c < len(node.pages) and i + c < n
               and node.tokens[c * self.ps:(c + 1) * self.ps]
               == self._page(tokens, i + c)):
            c += 1
        return c

    # -- queries -------------------------------------------------------

    def match(self, tokens) -> tuple[list, int]:
        """Longest page-aligned cached prefix of ``tokens``.

        Returns (pages, matched_token_count); touches the path for LRU.
        """
        self._clock += 1
        node, i, n = self.root, 0, len(tokens) // self.ps
        out: list = []
        while i < n:
            child = node.children.get(self._page(tokens, i))
            if child is None:
                break
            c = self._common_pages(child, tokens, i, n)
            child.t = self._clock
            out.extend(child.pages[:c])
            i += c
            if c < len(child.pages):  # partial edge: stop, no split on read
                break
            node = child
        return out, len(out) * self.ps

    def insert(self, tokens, pages) -> int:
        """Reference ``pages`` (one per full page of ``tokens``) in the trie.

        Walks the existing structure; where the trie already covers a page
        the EXISTING page is kept and the caller's duplicate stays private.
        Returns the number of newly adopted pages (each incref'd).
        """
        self._clock += 1
        n = min(len(tokens) // self.ps, len(pages))
        node, i, adopted = self.root, 0, 0
        while i < n:
            child = node.children.get(self._page(tokens, i))
            if child is None:
                new = _Node(tokens[i * self.ps:n * self.ps], pages[i:n], node)
                new.t = self._clock
                for p in new.pages:
                    self.pool.incref(p)
                adopted += len(new.pages)
                node.children[self._page(tokens, i)] = new
                return adopted
            c = self._common_pages(child, tokens, i, n)
            child.t = self._clock
            if c == len(child.pages):
                node, i = child, i + c
                continue
            # split the edge at the page boundary ``c`` (c >= 1: children
            # are keyed by their first page, so the first page matched)
            upper = _Node(child.tokens[:c * self.ps], child.pages[:c], node)
            upper.t = self._clock
            child.tokens = child.tokens[c * self.ps:]
            child.pages = child.pages[c:]
            child.parent = upper
            upper.children[child.tokens[:self.ps]] = child
            node.children[self._page(tokens, i)] = upper
            node, i = upper, i + c
        return adopted

    # -- eviction ------------------------------------------------------

    def _leaves(self) -> list:
        out, stack = [], [self.root]
        while stack:
            nd = stack.pop()
            stack.extend(nd.children.values())
            if nd is not self.root and not nd.children:
                out.append(nd)
        return out

    def evict(self, need: int) -> int:
        """Free >= ``need`` pages if possible by dropping LRU leaves whose
        pages are trie-only (refcount 1).  Returns the number freed —
        removing a leaf can expose its parent, so the scan repeats."""
        freed = 0
        while freed < need:
            cands = [nd for nd in self._leaves()
                     if all(self.pool.refs[p] == 1 for p in nd.pages)]
            if not cands:
                break
            victim = min(cands, key=lambda nd: nd.t)
            for p in victim.pages:
                self.pool.decref(p)
            freed += len(victim.pages)
            del victim.parent.children[victim.tokens[:self.ps]]
        return freed

    def n_pages(self) -> int:
        """Pages currently referenced by the trie (for stats/tests)."""
        total, stack = 0, [self.root]
        while stack:
            nd = stack.pop()
            total += len(nd.pages)
            stack.extend(nd.children.values())
        return total

    def audit(self) -> list:
        """Structural invariants, raising :class:`AuditError` on drift.

        Checks every reachable node: edge labels are whole pages
        (``len(tokens) == len(pages) * ps``), each child is keyed by its
        edge's first page of tokens (two siblings can never share a first
        page), parent back-pointers match the walk, non-root nodes are
        non-empty, no physical page appears at two trie nodes, and every
        referenced page's pool refcount covers the trie's reference.
        Returns the list of all referenced pages (one entry each) so
        :meth:`PagePool.audit` can fold them into its recomputation.
        """
        seen: set = set()
        out: list = []
        stack = [self.root]
        while stack:
            nd = stack.pop()
            if nd is not self.root:
                if not nd.pages:
                    raise AuditError("empty non-root trie edge")
                if len(nd.tokens) != len(nd.pages) * self.ps:
                    raise AuditError(
                        f"edge label {len(nd.tokens)} tokens != "
                        f"{len(nd.pages)} pages of {self.ps}")
            for key, child in nd.children.items():
                if child.parent is not nd:
                    raise AuditError("child parent pointer does not match")
                if tuple(child.tokens[:self.ps]) != tuple(key):
                    raise AuditError(
                        f"child keyed {key} but edge starts "
                        f"{child.tokens[:self.ps]}")
                stack.append(child)
            for p in nd.pages:
                if p in seen:
                    raise AuditError(f"page {p} referenced at two trie nodes")
                seen.add(p)
                if self.pool.refs[p] < 1:
                    raise AuditError(f"trie references freed page {p}")
                out.append(p)
        return out
