"""Deterministic fault injection for the serving stack (DESIGN.md §13).

The robustness contract — every request terminates with a definite
outcome, audits stay clean, non-faulted greedy outputs never change — is
only worth anything if it survives faults that actually happen.  This
module injects them on purpose, seeded and reproducible:

  forced preemption   — ``SlotPoolEngine._preempt_latest`` fires without
                        page pressure, exercising the requeue/resume path.
  trie-eviction storm — every evictable prefix-cache leaf is dropped at
                        once: prefix hits vanish mid-run, refcounts must
                        hold.
  page-pool squeeze   — a fraction of the free pages is allocated and held
                        for a few scheduler ticks: admissions see
                        exhaustion (requeue-with-retry), decode page
                        appends see it (preemption).  The held pages are
                        registered as an extra audit holder so the
                        refcount recomputation still balances.
  NaN/Inf KV poison   — non-finite payloads written into a slot's
                        EXCLUSIVE KV page (paged) or cache row (dense) —
                        the silent-corruption shape hybrid-format
                        accelerators must guard: ``core/numerics.py``
                        fp2fx conversion saturates ±inf and maps NaN -> 0,
                        so a bad scale row corrupts quietly while the
                        logits go non-finite loudly.  The scheduler's
                        numeric guards must quarantine exactly that slot.
  drafter desync      — a speculative slot's draft row is replaced with
                        junk: exact verification must reject it with the
                        outputs provably unchanged.
  burst straggler     — an artificial stall before a burst, flagged by the
                        ``StragglerMonitor`` the scheduler wires burst
                        wall times into.
  cancellation        — a random in-flight/queued request is cancelled
                        through the host ``cancel(rid)`` API.

Injection points (``ChaosMonkey.fire(eng, point)``):

  "tick"      — top of every scheduling-loop iteration, BEFORE admission:
                squeeze/release, eviction storms, cancellations.
  "pre_burst" — immediately before a decode/spec burst: forced
                preemptions, KV poison, stragglers.
  (spec drafting consults ``corrupt_drafts`` directly — the draft tensors
  only exist inside ``_spec_burst``.)

Determinism: one ``numpy`` Generator seeded by ``FaultPlan.seed`` drives
every decision, so a fixed seed + a fixed scheduling sequence replays the
same faults.  The scheduling sequence itself is wall-clock-free when every
request arrives at 0.0 with no deadlines — the regime the chaos bench and
tests run in.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Per-injection-point fault probabilities (all in [0, 1]).

    A zero-everything plan injects nothing; ``max_faults`` caps the total
    number of injected faults so a high-rate plan still lets the run
    finish its tail quietly."""
    seed: int = 0
    preempt_rate: float = 0.0       # pre_burst: force-preempt latest slot
    evict_storm_rate: float = 0.0   # tick: evict every prefix-cache leaf
    squeeze_rate: float = 0.0       # tick: hold free pages hostage
    squeeze_frac: float = 0.5       # fraction of free pages a squeeze takes
    squeeze_hold: int = 3           # scheduler ticks a squeeze lasts
    nan_kv_rate: float = 0.0        # pre_burst: poison exclusive KV
    nan_kind: str = "nan"           # "nan" | "inf" payload
    drafter_junk_rate: float = 0.0  # spec drafting: junk one slot's draft
    straggle_rate: float = 0.0      # pre_burst: artificial stall
    straggle_s: float = 0.0         # stall duration (seconds)
    cancel_rate: float = 0.0        # tick: cancel a random live request
    max_faults: int = 1 << 30


class ChaosMonkey:
    """Consults a :class:`FaultPlan` at the scheduler's injection points.

    Attach via ``SlotPoolEngine(..., chaos=ChaosMonkey(plan))`` (or
    ``serve(..., chaos=...)``).  ``faulted_rids`` collects the requests a
    KV poison actually touched — the one fault class that may legitimately
    alter a request's path (quarantine -> recompute), so benches exclude
    them from strict output-identity checks (recovery makes even those
    match unless the fp32 ladder exhausts).  ``log`` records every
    injected fault as a dict for post-mortem."""

    def __init__(self, plan: FaultPlan):
        if plan.nan_kind not in ("nan", "inf"):
            raise ValueError(f"unknown nan_kind {plan.nan_kind!r}")
        self.plan = plan
        self.rng = np.random.default_rng(plan.seed)
        self.log: list = []
        self.n_faults = 0
        self.faulted_rids: set = set()
        self._held: list = []           # squeezed pages (an audit holder)
        self._hold_left = 0
        self._tracer = None             # the engine's tracer, set by fire()

    # -- plumbing ------------------------------------------------------

    def _maybe(self, rate: float) -> bool:
        """One deterministic uniform per consult; fires iff under ``rate``
        with fault budget remaining."""
        u = self.rng.random()
        return u < rate and self.n_faults < self.plan.max_faults

    def _log(self, point: str, kind: str, **detail) -> None:
        self.n_faults += 1
        self.log.append(dict(point=point, kind=kind, **detail))
        if self._tracer is not None:
            # injected faults show up in the trace next to the spans they
            # perturb (DESIGN.md §15)
            self._tracer.instant(f"chaos.{kind}", cat="chaos", point=point,
                                 **detail)

    def summary(self) -> dict:
        by_kind: dict = {}
        for e in self.log:
            by_kind[e["kind"]] = by_kind.get(e["kind"], 0) + 1
        return {"faults": self.n_faults, "by_kind": by_kind,
                "faulted_rids": sorted(self.faulted_rids)}

    # -- injection points ----------------------------------------------

    def fire(self, eng, point: str) -> None:
        self._tracer = eng.obs.tracer
        if point == "tick":
            self._tick(eng)
        elif point == "pre_burst":
            self._pre_burst(eng)
        else:
            raise ValueError(f"unknown injection point {point!r}")

    def _tick(self, eng) -> None:
        self._squeeze_step(eng)
        if self._maybe(self.plan.evict_storm_rate) and eng.trie is not None:
            freed = eng.trie.evict(1 << 30)
            if freed:
                self._log("tick", "evict_storm", pages=freed)
        if (self._maybe(self.plan.squeeze_rate) and eng.paged
                and not self._held):
            take = int(eng.pool.free_pages * self.plan.squeeze_frac)
            pages = eng.pool.alloc(take) if take > 0 else None
            if pages:
                self._held = pages
                self._hold_left = max(1, self.plan.squeeze_hold)
                eng._extra_holders.append(self._held)
                self._log("tick", "squeeze", pages=len(pages))
        if self._maybe(self.plan.cancel_rate):
            u = self.rng.random()
            cands = sorted({rid for rid in eng.slot_rid if rid is not None}
                           | {r.rid for r in eng._queue})
            if cands:
                rid = cands[int(u * len(cands)) % len(cands)]
                eng.cancel(rid)
                self._log("tick", "cancel", rid=rid)

    def _pre_burst(self, eng) -> None:
        if self._maybe(self.plan.preempt_rate):
            if eng._preempt_latest():
                self._log("pre_burst", "preempt")
        if self._maybe(self.plan.nan_kv_rate):
            self._poison(eng)
        if self._maybe(self.plan.straggle_rate) and self.plan.straggle_s > 0:
            time.sleep(self.plan.straggle_s)
            self._log("pre_burst", "straggle", seconds=self.plan.straggle_s)

    # -- fault payloads ------------------------------------------------

    def _squeeze_step(self, eng) -> None:
        """Count a held squeeze down one tick; release the pages when it
        expires (refcounts flow back through the normal decref path)."""
        if not self._held:
            return
        self._hold_left -= 1
        if self._hold_left > 0:
            return
        eng._extra_holders.remove(self._held)
        for p in self._held:
            eng.pool.decref(p)
        self._log("tick", "squeeze_release", pages=len(self._held))
        self._held = []

    def _poison(self, eng) -> bool:
        """Write a non-finite payload into one active slot's KV.

        Paged: the slot-EXCLUSIVE (refcount-1) page holding the read
        frontier (position ``length - 1``) — decode writes only ever land
        in exclusive tail pages, so that is the realistic fault site, and
        poisoning a trie-shared page would corrupt OTHER requests, which
        even the chaos harness must never do.  Dense: every float leaf row
        of the slot (for fp2fx8 the int8 raws cannot hold a NaN — the
        fp32 scale rows carry the poison, exactly the Hyft-relevant
        fault).  The touched rid goes into ``faulted_rids``."""
        val = float("nan") if self.plan.nan_kind == "nan" else float("inf")
        u = self.rng.random()
        if eng.paged:
            ps = eng.scfg.page_size
            cands = []
            for s in range(eng.scfg.n_slots):
                if not eng.active[s]:
                    continue
                bi = (int(eng.lengths[s]) - 1) // ps
                if bi < len(eng.slot_pages[s]):
                    p = eng.slot_pages[s][bi]
                    if eng.pool.refs[p] == 1:
                        cands.append((s, p))
            if not cands:
                return False
            s, p = cands[int(u * len(cands)) % len(cands)]
            eng.cache["blocks"] = jax.tree.map(
                lambda lf: (lf.at[:, p].set(val)
                            if jnp.issubdtype(lf.dtype, jnp.floating)
                            else lf),
                eng.cache["blocks"])
        else:
            live = [s for s in range(eng.scfg.n_slots) if eng.active[s]]
            if not live:
                return False
            s = live[int(u * len(live)) % len(live)]
            if eng._axes is None:
                from repro.serve import scheduler as sched
                eng._axes = sched._cache_batch_axes(
                    eng.model, eng.params, eng.scfg.max_len,
                    eng.scfg.cache_dtype)

            def poi(lf, ax):
                if not jnp.issubdtype(lf.dtype, jnp.floating):
                    return lf
                m = jnp.moveaxis(lf, ax, 0)
                return jnp.moveaxis(m.at[s].set(val), 0, ax)

            eng.cache = jax.tree.map(poi, eng.cache, eng._axes)
        rid = eng.slot_rid[s]
        self.faulted_rids.add(rid)
        self._log("pre_burst", "nan_kv", rid=rid, slot=int(s),
                  payload=self.plan.nan_kind)
        return True

    def corrupt_drafts(self, eng, draft, n_draft, want):
        """Drafter-desync fault: replace one drafting slot's row with junk
        tokens at the full draft width.  Exact verification rejects every
        mismatching lane, so outputs are PROVABLY unchanged — the fault
        only costs the slot its speculative speedup for one step."""
        if not self._maybe(self.plan.drafter_junk_rate):
            return draft, n_draft
        u = self.rng.random()
        cands = [s for s in range(eng.scfg.n_slots) if want[s] > 0]
        if not cands:
            return draft, n_draft
        s = cands[int(u * len(cands)) % len(cands)]
        k = draft.shape[1]
        draft = np.array(draft)
        n_draft = np.array(n_draft)
        draft[s, :] = (eng.model.cfg.vocab - 1
                       - np.arange(k, dtype=np.int32) % 2)
        n_draft[s] = int(min(want[s], k))
        self._log("draft", "drafter_junk", rid=eng.slot_rid[s], slot=int(s))
        return draft, n_draft
