from repro.serve.engine import build_serve_step, generate  # noqa: F401
