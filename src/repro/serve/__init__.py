from repro.serve.engine import (  # noqa: F401
    build_decode_loop, build_serve_step, generate)
from repro.serve.scheduler import (  # noqa: F401
    Completion, Request, SlotPoolEngine, serve)
from repro.serve.spec import (  # noqa: F401
    ModelDrafter, NgramDrafter, build_spec_step)
