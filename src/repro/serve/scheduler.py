"""Continuous-batching scheduler: a slot-pool KV cache serving ragged traffic.

The lockstep ``engine.generate`` path serves ONE rectangular batch: every
sequence prefills together, decodes for the same horizon, and EOS is
ignored.  Real traffic is ragged — prompts of different lengths arriving at
different times, finishing after different numbers of tokens.  This module
serves that shape of load with three pieces:

  slot pool    — the KV cache is allocated ONCE with a fixed batch (slot)
                 dimension ``n_slots`` (dense or fp2fx8 layout); per-slot
                 host state tracks ``length`` (next write position),
                 ``active``, and the remaining token ``budget``.  A request
                 occupies a slot for exactly its own lifetime.
  ragged prefill — queued prompts are right-padded to a bucketed length and
                 prefilled as one batch (``prefill(..., lengths=...)``); the
                 per-row ``kv_len_mask`` contract makes padding invisible,
                 and each row's first token comes from the logits at its own
                 ``length - 1``.  The prefilled rows are scattered into free
                 slots while the rest of the pool keeps its cache.
  masked burst — decode advances ALL slots in one jitted ``lax.scan`` of
                 ``decode_burst`` steps: each step writes KV at per-slot
                 positions (``cache_update_ragged``), attends under the
                 per-slot ``kv_len_mask`` (arange <= length), samples, and
                 detects EOS / budget exhaustion ON DEVICE — a finished
                 slot's ``write_mask`` goes False, so it stops mutating its
                 cache mid-burst while its neighbours keep decoding.  The
                 host only sees the emitted tokens and the final per-slot
                 state, frees finished slots, and admits queued requests
                 into them before the next burst (insertion prefill).

``ServeConfig.scheduler`` picks the admission policy:

  continuous — admit into freed slots mid-decode; EOS (``eos_id``) frees a
               slot as soon as it fires.
  lockstep   — drain the whole pool before admitting the next group and
               ignore EOS: the PR 2 rectangular baseline generalized to
               ragged prompts, using the *same* burst arithmetic, so a
               benchmark comparison isolates the scheduling policy.

Greedy (temperature == 0) outputs are token-for-token identical to a solo
``engine.generate`` run of the same prompt — padding, slot position, and
pool neighbours are all invisible to a sequence's arithmetic.  The one
exception is the MoE family: capacity-bounded expert routing dispatches
tokens batch-globally, so any *batched* serving (this scheduler AND the
rectangular lockstep engine) couples a sequence's outputs to its
neighbours' tokens — inherent to dropped-token routing, not to the
scheduler.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from collections import deque
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ServeConfig
from repro.serve import engine

I32 = jnp.int32
PAD = -1  # emitted-token filler for slots that were idle during a burst step


@dataclasses.dataclass
class Request:
    """One generation request. ``arrival`` is in seconds after ``run()``
    starts (0 = already queued); requests must be submitted in arrival
    order."""
    rid: int
    tokens: Any                       # (prompt_len,) int token ids
    max_new: int
    frames: Any = None                # encdec: (frontend_len, frontend_dim)
    arrival: float = 0.0


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: list                      # generated ids (includes EOS if hit)
    prompt_len: int
    finished_at: float                # seconds after run() start
    arrival: float = 0.0

    @property
    def latency(self) -> float:
        return self.finished_at - self.arrival


def _bucket(n: int, lo: int = 4) -> int:
    """Next power of two >= n (>= lo) — bounds the number of distinct
    prefill compilations for ragged prompt lengths / admission group sizes."""
    b = lo
    while b < n:
        b *= 2
    return b


_BURST_CACHE: dict = {}
_SCATTER_CACHE: dict = {}
_AXES_CACHE: dict = {}


def _burst_key_cfg(scfg: ServeConfig) -> ServeConfig:
    """Burst compilations depend on the decode arithmetic, not the admission
    policy: lockstep mode ignores EOS, so normalize both fields and let the
    two schedulers share one compiled burst."""
    eos = scfg.eos_id if scfg.scheduler == "continuous" else None
    return dataclasses.replace(scfg, scheduler="", eos_id=eos)


def build_burst(model, scfg: ServeConfig, steps: int):
    """Jit'd (params, cache, tok, lengths, active, budget, key) ->
    (emitted (steps, slots), cache, tok, lengths, active, budget, key).

    One ``lax.scan`` of ``steps`` masked decode steps.  Every slot computes
    every step (uniform shapes), but only active slots write their KV
    (``write_mask``), consume budget, advance their length, or emit a token
    (idle rows emit PAD).  EOS and budget exhaustion flip ``active`` on
    device; the freed slot's cache is untouched from that step on.
    """
    kcfg = _burst_key_cfg(scfg)
    eos = kcfg.eos_id
    ck = (model.cfg, kcfg, steps)
    if ck in _BURST_CACHE:
        return _BURST_CACHE[ck]

    @functools.partial(jax.jit, donate_argnums=(1,))
    def burst(params, cache, tok, lengths, active, budget, key):
        def body(carry, _):
            cache_c, tok_c, len_c, act_c, bud_c, key_c = carry
            if scfg.temperature > 0:
                key_c, sub = jax.random.split(key_c)
            else:
                sub = key_c
            logits, cache_c = model.decode_step(params, cache_c, tok_c, len_c,
                                                write_mask=act_c)
            nxt = engine._sample(logits[:, -1, :], sub,
                                 scfg.temperature).astype(I32)
            emit = jnp.where(act_c, nxt, PAD)
            bud_c = bud_c - act_c.astype(I32)
            len_c = len_c + act_c.astype(I32)
            alive = act_c & (bud_c > 0)
            if eos is not None:
                alive = alive & (nxt != eos)
            tok_c = jnp.where(act_c, nxt, tok_c[:, 0])[:, None]
            return (cache_c, tok_c, len_c, alive, bud_c, key_c), emit

        carry, emits = jax.lax.scan(
            body, (cache, tok, lengths, active, budget, key), None,
            length=steps)
        cache, tok, lengths, active, budget, key = carry
        # returning the cache gives the donated input buffers an output to
        # alias with (true in-place burst on TPU)
        return emits, cache, tok, lengths, active, budget, key

    return engine._cache_put(_BURST_CACHE, ck, burst)


def _cache_batch_axes(model, params, max_len, dtype):
    """Per-leaf slot (batch) axis of the serving cache, discovered by
    diffing the abstract shapes at two batch sizes — layer-stacked leaves
    carry the batch on axis 1, the encoder memory on axis 0, etc."""
    ck = (model.cfg, max_len, str(dtype))
    if ck in _AXES_CACHE:
        return _AXES_CACHE[ck]
    s1 = jax.eval_shape(
        functools.partial(model.init_cache, params, 1, max_len, dtype))
    s2 = jax.eval_shape(
        functools.partial(model.init_cache, params, 2, max_len, dtype))

    def ax(a, b):
        for i, (x, y) in enumerate(zip(a.shape, b.shape)):
            if x != y:
                return i
        raise ValueError(f"no batch axis in cache leaf {a.shape}")

    return engine._cache_put(_AXES_CACHE, ck, jax.tree.map(ax, s1, s2))


def build_scatter(model, axes, max_len, dtype):
    """Jit'd (pool, new, slot_idx) -> pool with ``new``'s first
    ``len(slot_idx)`` batch rows written into the pool's slots.  The pool is
    donated — admission rewrites the slot rows in place."""
    ck = (model.cfg, max_len, str(dtype))
    if ck in _SCATTER_CACHE:
        return _SCATTER_CACHE[ck]

    @functools.partial(jax.jit, donate_argnums=(0,))
    def scatter(pool, new, slot_idx):
        # slot_idx is always padded to n_slots rows (duplicates carry the
        # same payload, so repeated writes are benign) — ONE compilation
        # regardless of how many slots an admission actually fills
        def s(p, n, ax):
            pm = jnp.moveaxis(p, ax, 0)
            nm = jnp.moveaxis(n, ax, 0)
            pm = pm.at[slot_idx].set(nm.astype(pm.dtype))
            return jnp.moveaxis(pm, 0, ax)

        return jax.tree.map(s, pool, new, axes)

    return engine._cache_put(_SCATTER_CACHE, ck, scatter)


class SlotPoolEngine:
    """Host-side scheduler around the slot-pool cache and the jitted burst.

    Pool state lives as numpy mirrors (tiny vectors) updated from each
    burst's outputs; the KV cache itself never leaves the device and is
    donated through every burst/scatter call.
    """

    def __init__(self, model, params, scfg: ServeConfig, key=None):
        from repro.models import resolve_attn_mode
        self.model = resolve_attn_mode(model, scfg.attn_mode)
        self.params = params
        self.scfg = scfg
        self.key = key if key is not None else jax.random.PRNGKey(0)
        n = scfg.n_slots
        self.cache = self.model.init_cache(params, n, scfg.max_len,
                                           scfg.cache_dtype)
        self.lengths = np.zeros(n, np.int32)
        self.active = np.zeros(n, bool)
        self.budget = np.zeros(n, np.int32)
        self.last_tok = np.zeros(n, np.int32)
        self.slot_rid: list[Optional[int]] = [None] * n
        self.outputs: dict[int, list] = {}
        self.requests: dict[int, Request] = {}
        self.completions: dict[int, Completion] = {}
        self._axes = _cache_batch_axes(self.model, params, scfg.max_len,
                                       scfg.cache_dtype)
        self._scatter = build_scatter(self.model, self._axes, scfg.max_len,
                                      scfg.cache_dtype)
        self._burst = build_burst(self.model, scfg,
                                  max(1, scfg.decode_burst))
        self._eos = scfg.eos_id if scfg.scheduler == "continuous" else None
        self.stats = {"admitted": 0, "bursts": 0, "prefills": 0,
                      "burst_steps": 0, "slot_steps_active": 0,
                      "peak_active": 0, "tokens_emitted": 0}

    # -- warmup --------------------------------------------------------

    def prewarm(self, max_prompt_len: int, frontend=None) -> None:
        """Compile every executable a run can hit — the burst, the scatter,
        and the ragged prefill at every (group, prompt) bucket shape.

        Admission shapes depend on arrival timing (how many requests are
        queued when slots free up), so without this a *timed* run may pay a
        jit trace mid-flight.  ``frontend``: (frontend_len, frontend_dim)
        for encdec models.
        """
        scfg = self.scfg
        gs, g = [], 1
        while g < scfg.n_slots:
            gs.append(g)
            g *= 2
        gs.append(_bucket(scfg.n_slots, lo=1))
        sps, sp = [], 4
        while sp < min(_bucket(max_prompt_len), scfg.max_len):
            sps.append(sp)
            sp *= 2
        sps.append(min(_bucket(max_prompt_len), scfg.max_len))
        prefill = engine.build_prefill(self.model)
        for g in sorted(set(gs)):
            for sp in sorted(set(sps)):
                batch = {"tokens": jnp.zeros((g, sp), I32),
                         "lengths": jnp.ones((g,), I32)}
                if frontend is not None:
                    batch["frames"] = jnp.zeros((g,) + tuple(frontend))
                fresh = self.model.init_cache(self.params, g, scfg.max_len,
                                              scfg.cache_dtype)
                jax.block_until_ready(prefill(self.params, fresh, batch)[0])
        n = scfg.n_slots
        fresh = self.model.init_cache(self.params, n, scfg.max_len,
                                      scfg.cache_dtype)
        self.cache = self._scatter(self.cache, fresh,
                                   jnp.arange(n, dtype=I32))
        out = self._burst(self.params, self.cache, jnp.zeros((n, 1), I32),
                          jnp.zeros(n, I32), jnp.zeros(n, bool),
                          jnp.zeros(n, I32), jax.random.PRNGKey(0))
        self.cache = out[1]
        jax.block_until_ready(out[0])

    # -- admission -----------------------------------------------------

    def _first_token(self, logits):
        """Sample (temperature > 0) or argmax the FIRST generated token from
        the ragged prefill logits — same contract as ``engine.generate``."""
        last = logits[:, -1, :]
        if self.scfg.temperature > 0:
            self.key, sub = jax.random.split(self.key)
            return engine._sample(last, sub, self.scfg.temperature)
        return jnp.argmax(last, -1)

    def admit(self, reqs: list[Request], now: float) -> None:
        """Ragged group prefill of ``reqs`` + insertion into free slots.

        Prompts are right-padded to a bucketed common length (and the group
        to a bucketed row count, bounding compilations); row ``b``'s true
        length rides in ``batch["lengths"]`` per the kv_len_mask contract.
        Rows whose request is already complete after its first token (EOS or
        ``max_new == 1``) never occupy a slot.
        """
        if not reqs:
            return
        free = [s for s in range(self.scfg.n_slots) if not self.active[s]
                and self.slot_rid[s] is None]
        assert len(reqs) <= len(free), "admitting more requests than slots"
        scfg = self.scfg
        lens = np.array([len(r.tokens) for r in reqs], np.int32)
        g = _bucket(len(reqs), lo=1)
        s_pad = min(_bucket(int(lens.max())), scfg.max_len)
        toks = np.zeros((g, s_pad), np.int32)
        glens = np.ones(g, np.int32)
        for b, r in enumerate(reqs):
            toks[b, :lens[b]] = np.asarray(r.tokens, np.int32)
        toks[len(reqs):] = toks[0]          # dummy rows: never scattered
        glens[:len(reqs)] = lens
        glens[len(reqs):] = lens[0]
        batch = {"tokens": jnp.asarray(toks), "lengths": jnp.asarray(glens)}
        if reqs[0].frames is not None:
            if any(r.frames is None for r in reqs):
                raise ValueError("mixed group: some requests carry encoder "
                                 "frames and some do not")
            fr = np.stack([np.asarray(r.frames) for r in reqs])
            fr = np.concatenate([fr, np.repeat(fr[:1], g - len(reqs), 0)], 0)
            batch["frames"] = jnp.asarray(fr)

        fresh = self.model.init_cache(self.params, g, scfg.max_len,
                                      scfg.cache_dtype)
        logits, new_cache, _ = engine.build_prefill(self.model)(
            self.params, fresh, batch)
        tok0 = np.asarray(self._first_token(logits), np.int32)
        self.stats["prefills"] += 1

        slot_idx, takers = [], []
        for b, r in enumerate(reqs):
            self.requests[r.rid] = r
            self.outputs[r.rid] = [int(tok0[b])]
            self.stats["tokens_emitted"] += 1
            self.stats["admitted"] += 1
            done = (r.max_new <= 1
                    or (self._eos is not None and int(tok0[b]) == self._eos))
            if done:
                self._finish(r.rid, now)
                continue
            s = free[len(takers)]
            takers.append(b)
            slot_idx.append(s)
            self.slot_rid[s] = r.rid
            self.lengths[s] = lens[b]
            self.budget[s] = r.max_new - 1
            self.last_tok[s] = tok0[b]
            self.active[s] = True
        if slot_idx:
            # reorder the prefilled rows so row j lands in slot_idx[j];
            # pad both index vectors to n_slots (repeating the last pair —
            # duplicate writes of identical rows) so the jitted scatter
            # compiles exactly once per pool
            pad = scfg.n_slots - len(slot_idx)
            order = np.array(takers + [takers[-1]] * pad, np.int32)
            slots = np.array(slot_idx + [slot_idx[-1]] * pad, np.int32)
            picked = jax.tree.map(
                lambda leaf, ax: jnp.take(leaf, jnp.asarray(order), axis=ax),
                new_cache, self._axes)
            self.cache = self._scatter(self.cache, picked,
                                       jnp.asarray(slots))
        self.stats["peak_active"] = max(self.stats["peak_active"],
                                        int(self.active.sum()))

    def _finish(self, rid: int, now: float) -> None:
        r = self.requests[rid]
        self.completions[rid] = Completion(
            rid=rid, tokens=self.outputs[rid], prompt_len=len(r.tokens),
            finished_at=now, arrival=r.arrival)

    # -- decode --------------------------------------------------------

    def burst(self, now: float) -> None:
        """One jitted burst of ``decode_burst`` masked steps + host
        bookkeeping: append emitted tokens, finalize newly freed slots."""
        was_active = self.active.copy()
        emits, self.cache, tok, lengths, active, budget, self.key = \
            self._burst(self.params, self.cache,
                        jnp.asarray(self.last_tok)[:, None],
                        jnp.asarray(self.lengths),
                        jnp.asarray(self.active),
                        jnp.asarray(self.budget), self.key)
        emits = np.asarray(emits)                       # (steps, n_slots)
        # np.array (not asarray): jax exports read-only views, but admission
        # writes per-slot entries into these host mirrors
        self.lengths = np.array(lengths)
        self.active = np.array(active)
        self.budget = np.array(budget)
        self.last_tok = np.array(tok)[:, 0]
        self.stats["bursts"] += 1
        self.stats["burst_steps"] += emits.shape[0]
        self.stats["slot_steps_active"] += int((emits != PAD).sum())
        for s in np.nonzero(was_active)[0]:
            toks = emits[:, s]
            toks = toks[toks != PAD].tolist()
            self.outputs[self.slot_rid[s]].extend(toks)
            self.stats["tokens_emitted"] += len(toks)
            if not self.active[s]:                      # freed on device
                self._finish(self.slot_rid[s], now)
                self.slot_rid[s] = None

    # -- the serving loop ----------------------------------------------

    def run(self, requests: list[Request]) -> dict[int, Completion]:
        """Serve ``requests`` (sorted by ``arrival``) to completion."""
        for r in requests:  # reject malformed requests BEFORE serving any —
            # a mid-run failure would discard every in-flight completion
            if r.max_new < 1:
                raise ValueError(f"request {r.rid}: max_new must be >= 1")
            if len(r.tokens) + r.max_new > self.scfg.max_len:
                raise ValueError(
                    f"request {r.rid}: prompt {len(r.tokens)} + max_new "
                    f"{r.max_new} exceeds max_len {self.scfg.max_len}")
        queue = deque(sorted(requests, key=lambda r: r.arrival))
        t0 = time.perf_counter()
        continuous = self.scfg.scheduler == "continuous"
        while queue or self.active.any():
            now = time.perf_counter() - t0
            free = int((~self.active).sum())  # slot_rid is None iff inactive
            can_admit = continuous or not self.active.any()
            batch = []
            while (can_admit and queue and len(batch) < free
                   and queue[0].arrival <= now):
                batch.append(queue.popleft())
            if batch:
                self.admit(batch, time.perf_counter() - t0)
            if not self.active.any():
                if queue:  # idle: wait for the next arrival
                    now = time.perf_counter() - t0
                    time.sleep(max(0.0, min(queue[0].arrival - now, 0.01)))
                continue
            self.burst(time.perf_counter() - t0)
        return self.completions


def serve(model, params, requests: list[Request], scfg: ServeConfig,
          key=None) -> dict[int, Completion]:
    """One-shot entry: build a slot-pool engine, serve, return completions."""
    eng = SlotPoolEngine(model, params, scfg, key=key)
    eng.run(requests)
    return eng.completions
