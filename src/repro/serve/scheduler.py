"""Continuous-batching scheduler: a slot-pool KV cache serving ragged traffic.

The lockstep ``engine.generate`` path serves ONE rectangular batch: every
sequence prefills together, decodes for the same horizon, and EOS is
ignored.  Real traffic is ragged — prompts of different lengths arriving at
different times, finishing after different numbers of tokens.  This module
serves that shape of load with three pieces:

  slot pool    — the KV cache is allocated ONCE with a fixed batch (slot)
                 dimension ``n_slots`` (dense or fp2fx8 layout); per-slot
                 host state tracks ``length`` (next write position),
                 ``active``, ``prefilling``, and the remaining token
                 ``budget``.  A request occupies a slot for exactly its own
                 lifetime.
  chunked prefill — admission is host bookkeeping only; the prompt tokens
                 are pushed through ``engine.build_prefill_chunk`` (the
                 chunked attend-at-offset primitive, DESIGN.md §12) IN
                 PLACE over the slot's own cache rows: every prefilling row
                 writes up to ``ServeConfig.prefill_chunk`` tokens at its
                 own offset per call, multiple short prompts pack into one
                 bucketed call (``pack_prefill``), and long prompts span
                 several calls interleaved with decode bursts — so decode
                 never stalls longer than one chunk, and prompts longer
                 than any single bucket still serve.  Each completed row's
                 first token comes from its lane ``length - 1`` logits.
  masked burst — decode advances ALL slots in one jitted ``lax.scan`` of
                 ``decode_burst`` steps: each step writes KV at per-slot
                 positions (``cache_update_ragged``), attends under the
                 per-slot ``kv_len_mask`` (arange <= length), samples, and
                 detects EOS / budget exhaustion ON DEVICE — a finished
                 slot's ``write_mask`` goes False, so it stops mutating its
                 cache mid-burst while its neighbours keep decoding.  The
                 host only sees the emitted tokens and the final per-slot
                 state, frees finished slots, and admits queued requests
                 into them before the next burst (insertion prefill).

``ServeConfig.scheduler`` picks the admission policy:

  continuous — admit into freed slots mid-decode; EOS (``eos_id``) frees a
               slot as soon as it fires.
  lockstep   — drain the whole pool before admitting the next group and
               ignore EOS: the PR 2 rectangular baseline generalized to
               ragged prompts, using the *same* burst arithmetic, so a
               benchmark comparison isolates the scheduling policy.
  spec       — continuous admission + speculative decode bursts
               (``repro.serve.spec``, DESIGN.md §11): each burst drafts up
               to ``draft_k`` tokens per slot (ragged across the batch),
               verifies them in ONE prefill-shaped model call, keeps the
               longest accepted prefix (EOS/budget on accepted tokens
               only), and rolls the KV back — greedy outputs are
               token-for-token identical to continuous/vanilla decode.

``ServeConfig.kv_layout`` picks the cache layout (DESIGN.md §10):

  dense — one (max_len,) KV stripe per slot (the PR 3 layout): memory
          scales with the worst case whether or not a request uses it.
  paged — a global pool of fixed-size pages (``repro.serve.kvpool``) with
          per-slot block tables: admission allocates just the prompt's
          pages, decode bursts append pages on demand, exhaustion preempts
          the LATEST-ARRIVAL slot — arrival order is the priority, ties by
          rid — (requeued through normal admission with its generated
          tokens folded into the prompt — greedy continuation is
          identical), and ``prefix_cache`` shares the pages of previously
          seen prompt prefixes through a radix trie, so cached tokens skip
          prefill entirely (only the un-cached suffix goes through
          ``prefill_chunk`` calls).

Greedy (temperature == 0) outputs are token-for-token identical to a solo
``engine.generate`` run of the same prompt — padding, slot position, and
pool neighbours are all invisible to a sequence's arithmetic.  The one
exception is the MoE family: capacity-bounded expert routing dispatches
tokens batch-globally, so any *batched* serving (this scheduler AND the
rectangular lockstep engine) couples a sequence's outputs to its
neighbours' tokens — inherent to dropped-token routing, not to the
scheduler.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from collections import deque
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ServeConfig
from repro.obs import Obs, compile_watch
from repro.obs import numerics as obs_numerics
from repro.serve import engine, kvpool

I32 = jnp.int32
PAD = -1  # emitted-token filler for slots that were idle during a burst step


@dataclasses.dataclass
class Request:
    """One generation request. ``arrival`` is in seconds after ``run()``
    starts (0 = already queued); requests must be submitted in arrival
    order.  ``deadline`` (seconds after run() start, like ``arrival``) is a
    hard TTL: a request still unfinished at its deadline is expired with a
    structured ``deadline`` failure and its slot/pages are freed within one
    burst (DESIGN.md §13)."""
    rid: int
    tokens: Any                       # (prompt_len,) int token ids
    max_new: int
    frames: Any = None                # encdec: (frontend_len, frontend_dim)
    arrival: float = 0.0
    deadline: Optional[float] = None
    # internal: a preempted request requeued mid-generation (its prompt
    # already carries the tokens generated so far; outputs are appended)
    resume: bool = False


@dataclasses.dataclass
class FailureInfo:
    """Why a request ended without running to EOS/budget (DESIGN.md §13).

    ``reason`` is one of: ``invalid`` (malformed request rejected at
    submission), ``queue_full`` (admission backpressure), ``deadline``
    (TTL expired), ``numeric_fault`` (non-finite logits survived the
    quarantine -> fp32-retry ladder), ``retries_exhausted`` (the request
    was requeued — preemption or quarantine — more than
    ``ServeConfig.max_retries`` times).  The partial tokens generated
    before the failure stay on the ``Completion``."""
    reason: str
    detail: str = ""
    retries: int = 0


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: list                      # generated ids (includes EOS if hit)
    prompt_len: int
    finished_at: float                # seconds after run() start
    arrival: float = 0.0
    # per-token emission timestamps (seconds after run() start, stamped at
    # burst/prefill completion — tokens emitted by one burst share one
    # stamp).  token_times[0] - arrival is the TTFT; successive diffs are
    # the inter-token (TBT) gaps the chunked-prefill scheduling bounds.
    token_times: list = dataclasses.field(default_factory=list)
    # robustness outcome: every request terminates with a definite one —
    # ok (finished), cancelled (host cancel/shutdown, partial tokens), or
    # failure (structured reason, partial tokens)
    cancelled: bool = False
    failure: Optional[FailureInfo] = None

    @property
    def ok(self) -> bool:
        return not self.cancelled and self.failure is None

    @property
    def latency(self) -> float:
        return self.finished_at - self.arrival

    @property
    def ttft(self) -> Optional[float]:
        """Time to first token, or None when the request never emitted one
        (failed/cancelled/zero-token) — aggregations must skip None rather
        than fold total latency into the TTFT percentiles."""
        if not self.token_times:
            return None
        return self.token_times[0] - self.arrival


def _bucket(n: int, lo: int = 4) -> int:
    """Next power of two >= n (>= lo) — bounds the number of distinct
    prefill compilations for ragged prompt lengths / admission group sizes."""
    b = lo
    while b < n:
        b *= 2
    return b


_BURST_CACHE: dict = {}
_SCATTER_CACHE: dict = {}
_AXES_CACHE: dict = {}


def _burst_key_cfg(scfg: ServeConfig) -> ServeConfig:
    """Burst compilations depend on the decode arithmetic, not the admission
    policy: lockstep mode ignores EOS, so normalize both fields and let the
    schedulers share one compiled burst (spec honors EOS like continuous).
    The chunk-scheduling knobs are admission policy too — a prefill-chunk
    executable is keyed by its width alone, so chunked and whole-prompt
    runs share compilations — and so are the host-only robustness knobs
    (audit cadence, queue bound, retry budget): none of them changes the
    burst arithmetic."""
    eos = scfg.eos_id if scfg.scheduler in ("continuous", "spec") else None
    return dataclasses.replace(scfg, scheduler="", eos_id=eos,
                               prefill_chunk=0, pack_prefill=True,
                               audit=False, max_queue=0, max_retries=0)


TTL_NONE = 1 << 30  # "no deadline" sentinel: never decrements to zero


def build_burst(model, scfg: ServeConfig, steps: int):
    """Jit'd (params, cache, tok, lengths, active, budget, ttl, key) ->
    (emitted (steps, slots), oks (steps, slots), cache, tok, lengths,
    active, budget, ttl, key, tstats).

    One ``lax.scan`` of ``steps`` masked decode steps.  Every slot computes
    every step (uniform shapes), but only active slots write their KV
    (``write_mask``), consume budget, advance their length, or emit a token
    (idle rows emit PAD).  EOS, budget exhaustion, and TTL expiry flip
    ``active`` on device; the freed slot's cache is untouched from that
    step on.  ``ttl`` is the per-slot step allowance the host derived from
    the request's wall-clock deadline (``TTL_NONE`` = no deadline): a slot
    whose allowance runs out stops decoding MID-BURST instead of overrunning
    its deadline by up to ``steps`` tokens.  ``oks`` is the per-step numeric
    health bit — False where an ACTIVE slot's next-token logits went
    non-finite (the host quarantines that slot; idle rows report True) —
    the cheap all-finite reduction the robustness layer keys on
    (DESIGN.md §13).  ``tstats`` is the per-burst hybrid-format telemetry
    dict (DESIGN.md §15): empty when ``scfg.telemetry`` is off (the flag is
    part of the compile key), else the softmax-input exponent range over
    the burst plus fp2fx8 scale/saturation stats of the final cache —
    computed in-jit at the cost of a few row reductions per step.
    """
    kcfg = _burst_key_cfg(scfg)
    eos = kcfg.eos_id
    ck = (model.cfg, kcfg, steps)
    if ck in _BURST_CACHE:
        return _BURST_CACHE[ck]

    @functools.partial(jax.jit, donate_argnums=(1,))
    def burst(params, cache, tok, lengths, active, budget, ttl, key):
        def body(carry, _):
            cache_c, tok_c, len_c, act_c, bud_c, ttl_c, key_c = carry
            if scfg.temperature > 0:
                key_c, sub = jax.random.split(key_c)
            else:
                sub = key_c
            with jax.named_scope("burst_step"):
                logits, cache_c = model.decode_step(params, cache_c, tok_c,
                                                    len_c, write_mask=act_c)
            last = logits[:, -1, :]
            ok = jnp.isfinite(last).all(-1) | ~act_c
            nxt = engine._sample(last, sub, scfg.temperature,
                                 scfg.top_k, scfg.top_p).astype(I32)
            emit = jnp.where(act_c, nxt, PAD)
            bud_c = bud_c - act_c.astype(I32)
            len_c = len_c + act_c.astype(I32)
            ttl_c = ttl_c - act_c.astype(I32)
            alive = act_c & (bud_c > 0) & (ttl_c > 0)
            if eos is not None:
                alive = alive & (nxt != eos)
            tok_c = jnp.where(act_c, nxt, tok_c[:, 0])[:, None]
            ys = (emit, ok)
            if kcfg.telemetry:
                ys = ys + (obs_numerics.logit_stats(last, act_c),)
            return (cache_c, tok_c, len_c, alive, bud_c, ttl_c, key_c), ys

        carry, ys = jax.lax.scan(
            body, (cache, tok, lengths, active, budget, ttl, key), None,
            length=steps)
        cache, tok, lengths, active, budget, ttl, key = carry
        if kcfg.telemetry:
            emits, oks, zs = ys
            tstats = dict(obs_numerics.reduce_logit_stats(zs),
                          **obs_numerics.format_stats(cache))
        else:
            emits, oks = ys
            tstats = {}
        # returning the cache gives the donated input buffers an output to
        # alias with (true in-place burst on TPU)
        return emits, oks, cache, tok, lengths, active, budget, ttl, key, \
            tstats

    return engine._cache_put(_BURST_CACHE, ck, burst)


def _cache_batch_axes(model, params, max_len, dtype):
    """Per-leaf slot (batch) axis of the serving cache, discovered by
    diffing the abstract shapes at two batch sizes — layer-stacked leaves
    carry the batch on axis 1, the encoder memory on axis 0, etc."""
    ck = (model.cfg, max_len, str(dtype))
    if ck in _AXES_CACHE:
        return _AXES_CACHE[ck]
    s1 = jax.eval_shape(
        functools.partial(model.init_cache, params, 1, max_len, dtype))
    s2 = jax.eval_shape(
        functools.partial(model.init_cache, params, 2, max_len, dtype))

    def ax(a, b):
        for i, (x, y) in enumerate(zip(a.shape, b.shape)):
            if x != y:
                return i
        raise ValueError(f"no batch axis in cache leaf {a.shape}")

    return engine._cache_put(_AXES_CACHE, ck, jax.tree.map(ax, s1, s2))


def build_scatter(model, axes, max_len, dtype):
    """Jit'd (pool, new, slot_idx) -> pool with ``new``'s first
    ``len(slot_idx)`` batch rows written into the pool's slots.  The pool is
    donated — admission rewrites the slot rows in place."""
    ck = (model.cfg, max_len, str(dtype))
    if ck in _SCATTER_CACHE:
        return _SCATTER_CACHE[ck]

    @functools.partial(jax.jit, donate_argnums=(0,))
    def scatter(pool, new, slot_idx):
        # slot_idx is always padded to n_slots rows (duplicates carry the
        # same payload, so repeated writes are benign) — ONE compilation
        # regardless of how many slots an admission actually fills
        def s(p, n, ax):
            pm = jnp.moveaxis(p, ax, 0)
            nm = jnp.moveaxis(n, ax, 0)
            pm = pm.at[slot_idx].set(nm.astype(pm.dtype))
            return jnp.moveaxis(pm, 0, ax)

        return jax.tree.map(s, pool, new, axes)

    return engine._cache_put(_SCATTER_CACHE, ck, scatter)


_ENCODE_CACHE: dict = {}


def build_encode(model):
    """Jit'd (params, frames) -> encoder memory — chunked encdec admission
    runs the encoder once per admitted group and installs the memory rows
    into the slot cache before any ``prefill_chunk`` call (one compile per
    bucketed group shape)."""
    ck = model.cfg
    if ck in _ENCODE_CACHE:
        return _ENCODE_CACHE[ck]
    return engine._cache_put(
        _ENCODE_CACHE, ck, jax.jit(lambda p, fr: model.encode(p, fr)))


# legacy ``stats`` keys, now counters/gauges in the Obs metrics registry
# (the ``SlotPoolEngine.stats`` property reconstructs the old dict) — the
# README "Observability" section documents the key -> metric mapping
_STAT_COUNTERS = (
    "admitted", "bursts", "prefills", "burst_steps", "slot_steps_active",
    "tokens_emitted", "prompt_tokens", "prefill_tokens", "cached_tokens",
    "prefix_hits", "preemptions", "model_calls", "spec_steps",
    "draft_tokens", "accepted_tokens", "rejected", "expired", "cancelled",
    "quarantines", "fp32_retries", "failures", "stragglers", "audits")
_STAT_GAUGES = ("peak_active", "pages_peak")


class SlotPoolEngine:
    """Host-side scheduler around the slot-pool cache and the jitted burst.

    Pool state lives as numpy mirrors (tiny vectors) updated from each
    burst's outputs; the KV cache itself never leaves the device and is
    donated through every burst/scatter call.
    """

    def __init__(self, model, params, scfg: ServeConfig, key=None,
                 draft=None, chaos=None, obs: Optional[Obs] = None):
        from repro.distributed.fault_tolerance import StragglerMonitor
        from repro.models import resolve_attn_mode
        self.model = resolve_attn_mode(model, scfg.attn_mode)
        self.params = params
        self.scfg = scfg
        # observability bundle (DESIGN.md §15): a fresh disabled-tracer Obs
        # per engine by default, so benchmark engines never share counters
        self.obs = obs if obs is not None else Obs()
        self.key = key if key is not None else jax.random.PRNGKey(0)
        n = scfg.n_slots
        if scfg.max_queue < 0:
            raise ValueError("max_queue must be >= 0 (0 = unbounded)")
        if scfg.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        # fault-injection harness (repro/serve/chaos.py): consulted at the
        # named injection points when attached; None in production
        self.chaos = chaos
        if scfg.scheduler not in ("continuous", "lockstep", "spec"):
            raise ValueError(f"unknown scheduler {scfg.scheduler!r}")
        if scfg.kv_layout not in ("dense", "paged"):
            raise ValueError(f"unknown kv_layout {scfg.kv_layout!r}")
        self.spec = scfg.scheduler == "spec"
        self.drafter = None
        if self.spec:
            if scfg.temperature > 0:
                raise ValueError(
                    "scheduler='spec' is greedy-only (temperature == 0): "
                    "sampled speculative acceptance needs distribution-"
                    "level rejection sampling, not the top-k/top-p filters")
            if self.model.init_paged_cache is None:
                raise ValueError(
                    "scheduler='spec' needs an attention-family model "
                    "(dense/moe/vlm): SSM/hybrid/encdec state has no O(1) "
                    "rollback, so those families serve non-speculatively")
            if scfg.draft_k < 1:
                raise ValueError("draft_k must be >= 1")
            from repro.serve import spec as spec_mod
            self.drafter = spec_mod.make_drafter(scfg, self.model.cfg,
                                                 draft=draft)
        self.paged = scfg.kv_layout == "paged"
        self.trie = None
        if self.paged:
            if self.model.init_paged_cache is None:
                raise ValueError(
                    "kv_layout='paged' needs an attention-family model "
                    "(dense/moe/vlm); SSM/hybrid/encdec serve dense")
            if scfg.page_size < 1:
                raise ValueError("page_size must be >= 1")
            self.n_blocks = -(-scfg.max_len // scfg.page_size)
            n_pages = scfg.n_pages or n * self.n_blocks
            if n_pages < self.n_blocks:
                raise ValueError(
                    f"n_pages={n_pages} cannot hold one max_len={scfg.max_len}"
                    f" request ({self.n_blocks} pages of {scfg.page_size})")
            self.pool = kvpool.PagePool(n_pages)
            if scfg.prefix_cache:
                self.trie = kvpool.RadixTrie(self.pool, scfg.page_size)
            self.slot_pages: list[list] = [[] for _ in range(n)]
            self.block_tables = np.zeros((n, self.n_blocks), np.int32)
            self.cache = dict(
                self.model.init_paged_cache(params, n_pages, scfg.page_size,
                                            scfg.cache_dtype),
                block_tables=jnp.asarray(self.block_tables))
        else:
            if scfg.prefix_cache:
                raise ValueError("prefix_cache requires kv_layout='paged'")
            self.cache = self.model.init_cache(params, n, scfg.max_len,
                                               scfg.cache_dtype)
        if scfg.prefill_chunk < 0:
            raise ValueError("prefill_chunk must be >= 0 (0 = whole prompt)")
        self.lengths = np.zeros(n, np.int32)
        self.active = np.zeros(n, bool)
        self.prefilling = np.zeros(n, bool)   # admitted, prompt not yet fed
        self.budget = np.zeros(n, np.int32)
        self.last_tok = np.zeros(n, np.int32)
        self.slot_rid: list[Optional[int]] = [None] * n
        # the prompt a slot was admitted with (a preempted resume carries
        # its generated tokens folded in) — chunk admission slices pending
        # tokens out of it, and trie publication reads it at completion
        self.slot_prompt: list[Optional[np.ndarray]] = [None] * n
        self.outputs: dict[int, list] = {}
        self.out_times: dict[int, list] = {}  # per-token emission stamps
        self.requests: dict[int, Request] = {}
        self.completions: dict[int, Completion] = {}
        self._queue: deque = deque()   # arrived, waiting (bounded)
        self._pending: deque = deque()  # submitted, not yet arrived
        # chunk prefill writes attention rows in place (the kv_index <=
        # position mask hides a previous occupant's stale KV), but
        # recurrent-state families CONTINUE from the slot's stored state,
        # so their admission scatters fresh zero rows first
        self._needs_reset = self.model.init_paged_cache is None
        self._encode = (build_encode(self.model)
                        if self.model.encode is not None else None)
        # the scatter doubles as the dense quarantine scrub, so attention
        # families build it lazily on the first fault (_scrub_dense_slot)
        self._axes = self._scatter = None
        if not self.paged and self._needs_reset:
            self._axes = _cache_batch_axes(self.model, params, scfg.max_len,
                                           scfg.cache_dtype)
            self._scatter = build_scatter(self.model, self._axes,
                                          scfg.max_len, scfg.cache_dtype)
        if self.spec:
            from repro.serve import spec as spec_mod
            self._spec_step = spec_mod.build_spec_step(
                self.model, _burst_key_cfg(scfg), scfg.draft_k)
        else:
            self._burst = build_burst(self.model, scfg,
                                      max(1, scfg.decode_burst))
        self._eos = (scfg.eos_id
                     if scfg.scheduler in ("continuous", "spec") else None)
        # --- robustness state (DESIGN.md §13) ---
        self.retries: dict[int, int] = {}        # requeues per rid
        self.numeric_faults: dict[int, int] = {}  # quarantines per rid
        self._cancels: set = set()               # rids to cancel next check
        # page lists held by parties other than slots/trie (the chaos
        # harness's pool squeeze) — folded into audit recomputation
        self._extra_holders: list = []
        # burst wall-time EMA + outlier flagging; also the per-step time
        # estimate behind the device-side deadline TTL
        self.straggler = StragglerMonitor()
        self._step_ema = 0.0
        self._t0: Optional[float] = None         # run() epoch, for shutdown
        # the fp32 fallback engine must fail structurally, never recurse
        self._allow_fp32_retry = True
        self._zero_pages = None                  # lazy jitted page scrub
        # --- metrics (DESIGN.md §15) ---
        # the legacy ``stats`` dict is now a read-only view over the
        # registry (see the ``stats`` property); every counter/gauge lives
        # under serve.<key> with scheduler+family labels
        self._labels = dict(scheduler=scfg.scheduler,
                            family=self.model.cfg.family)
        reg = self.obs.metrics
        self._counters = {
            k: reg.counter(f"serve.{k}", **self._labels)
            for k in _STAT_COUNTERS}
        self._gauges = {
            k: reg.gauge(f"serve.{k}", **self._labels)
            for k in _STAT_GAUGES + ("queue_depth", "slot_occupancy",
                                     "pages_in_use")}
        self._hists = {
            k: reg.histogram(f"serve.{k}", **self._labels)
            for k in ("ttft_s", "tbt_s", "burst_wall_s")}
        # fp→fx convert volume at the §14 boundaries: elements quantized
        # per KV-cache token write (k + v rows), counted host-side
        self._quantized = scfg.cache_dtype == "fp2fx8"
        if self._quantized:
            cfg = self.model.cfg
            heads = getattr(cfg, "n_kv_heads", None) or getattr(
                cfg, "n_heads", 1)
            self._converts_per_tok = (2 * cfg.n_layers * heads
                                      * getattr(cfg, "d_head", 1))
            self.obs.numerics.kv_int8_total = obs_numerics.int8_size(
                self.cache)
        else:
            self._converts_per_tok = 0

    # -- metrics helpers (DESIGN.md §15) --------------------------------

    def _count(self, key: str, n: int = 1) -> None:
        self._counters[key].inc(n)

    def _peak(self, key: str, v: float) -> None:
        self._gauges[key].track_max(v)

    @property
    def stats(self) -> dict:
        """Back-compat view: the legacy ad-hoc stats dict, reconstructed
        read-only from the metrics registry."""
        d = {}
        for k in ("admitted", "bursts", "prefills", "burst_steps",
                  "slot_steps_active"):
            d[k] = self._counters[k].value
        d["peak_active"] = int(self._gauges["peak_active"].value)
        for k in ("tokens_emitted", "prompt_tokens", "prefill_tokens",
                  "cached_tokens", "prefix_hits", "preemptions"):
            d[k] = self._counters[k].value
        d["pages_peak"] = int(self._gauges["pages_peak"].value)
        for k in ("model_calls", "spec_steps", "draft_tokens",
                  "accepted_tokens", "rejected", "expired", "cancelled",
                  "quarantines", "fp32_retries", "failures", "stragglers",
                  "audits"):
            d[k] = self._counters[k].value
        return d

    def _record_completion(self, c: Completion) -> None:
        """Latency histograms at completion time — TTFT (skipping None)
        and per-gap TBT — so metric aggregates reconcile with post-hoc
        numbers computed from the Completion records by construction."""
        t = c.ttft
        if t is not None:
            self._hists["ttft_s"].observe(t)
        tt = c.token_times
        for i in range(1, len(tt)):
            self._hists["tbt_s"].observe(tt[i] - tt[i - 1])

    def _count_converts(self, n_tokens: int) -> None:
        """fp→fx convert volume for ``n_tokens`` KV-cache token writes
        (the §14 quantize boundary; no-op for unquantized caches)."""
        if self._quantized and n_tokens > 0:
            self.obs.numerics.add_converts(n_tokens * self._converts_per_tok)

    # -- warmup --------------------------------------------------------

    def prewarm(self, max_prompt_len: int, frontend=None) -> None:
        """Compile every executable a run can hit — the burst and the
        prefill-chunk call at every width admission can bucket to (plus the
        encoder + zero-row scatter for recurrent-state families).

        Admission shapes depend on arrival timing (how many requests are
        queued when slots free up), so without this a *timed* run may pay a
        jit trace mid-flight.  Chunk calls always run all ``n_slots`` rows
        and are keyed by width alone, so the warm grid is one-dimensional —
        far fewer compilations than the old (group, prompt) bucket grid.
        ``frontend``: (frontend_len, frontend_dim) for encdec models.
        """
        scfg = self.scfg
        n = scfg.n_slots
        tracer = self.obs.tracer
        with compile_watch(tracer, enabled=tracer.enabled), \
                tracer.span("prewarm", max_prompt_len=max_prompt_len):
            cap = min(_bucket(max_prompt_len), scfg.max_len)
            c0 = scfg.prefill_chunk
            widths, b = set(), 4
            while b < cap:
                widths.add(min(c0, b) if c0 > 0 else b)
                b *= 2
            widths.add(min(c0, cap) if c0 > 0 else cap)
            if frontend is not None and self._encode is not None:
                g, g_top = 1, _bucket(n, lo=1)
                while True:
                    jax.block_until_ready(self._encode(
                        self.params, jnp.zeros((g,) + tuple(frontend))))
                    if g >= g_top:
                        break
                    g *= 2
            if not self.paged and self._needs_reset:
                fresh = self.model.init_cache(self.params, n, scfg.max_len,
                                              scfg.cache_dtype)
                self.cache = self._scatter(self.cache, fresh,
                                           jnp.arange(n, dtype=I32))
            # cost capture (DESIGN.md §16) must happen BEFORE each
            # executing call: the executables donate the cache buffer, and
            # ``lower`` at live args is the only time the shapes are in
            # hand.  XLA counts scan bodies once, so the trip factor
            # carries the layers-scan (and burst-steps) product.
            from repro.roofline.analysis import scan_trip_factor
            book = self.obs.profile
            cfg = self.model.cfg
            layers = scan_trip_factor(cfg, "decode", 1, 1, 1)
            for w in sorted(widths):
                pc = engine.build_prefill_chunk(
                    self.model, _burst_key_cfg(scfg), w)
                args = (self.params, self.cache, jnp.zeros((n, w), I32),
                        jnp.zeros(n, I32), jnp.ones(n, I32),
                        jnp.zeros(n, bool))
                book.record(f"prefill_chunk[w={w}]", pc, *args,
                            trip_factor=scan_trip_factor(
                                cfg, "prefill", w, 1, 1))
                # gate all-False: every row computes but none writes, so the
                # live pool is untouched — no scratch/restore dance needed
                out, self.cache = pc(*args)
                jax.block_until_ready(out)
            if self.spec:
                K = self.scfg.draft_k
                args = (self.params, self.cache, jnp.zeros((n, 1), I32),
                        jnp.zeros((n, K), I32), jnp.zeros(n, I32),
                        jnp.zeros(n, I32), jnp.zeros(n, bool),
                        jnp.zeros(n, I32))
                book.record("spec_step", self._spec_step, *args,
                            trip_factor=layers)
                out = self._spec_step(*args)
                self.cache = out[1]
            else:
                args = (self.params, self.cache, jnp.zeros((n, 1), I32),
                        jnp.zeros(n, I32), jnp.zeros(n, bool),
                        jnp.zeros(n, I32), jnp.full(n, TTL_NONE, I32),
                        jax.random.PRNGKey(0))
                book.record("decode_burst", self._burst, *args,
                            trip_factor=max(1, scfg.decode_burst) * layers)
                out = self._burst(*args)
                self.cache = out[2]
            jax.block_until_ready(out[0])

    # -- admission -----------------------------------------------------

    def _first_token(self, last):
        """Sample (temperature > 0) or argmax the FIRST generated token from
        the (B, V) next-token logits a completed prefill returned — same
        contract as ``engine.generate``."""
        if self.scfg.temperature > 0:
            self.key, sub = jax.random.split(self.key)
            return engine._sample(last, sub, self.scfg.temperature,
                                  self.scfg.top_k, self.scfg.top_p)
        return jnp.argmax(last, -1)

    def _register(self, r: Request) -> None:
        """First sighting of a request: create its output/trace records (a
        resume keeps the ORIGINAL request — its prompt, arrival, and
        deadline — so preemption folding and TTL stay anchored to it)."""
        if r.rid not in self.requests:
            self.requests[r.rid] = r
            self.outputs[r.rid] = []
            self.out_times[r.rid] = []

    def _start_prefill(self, s: int, r: Request, start: int) -> None:
        """Host bookkeeping that puts ``r`` into slot ``s`` in the
        ``prefilling`` state with ``start`` tokens already cached (prefix
        hits); ``_prefill_step`` feeds the rest chunk by chunk."""
        if not r.resume:
            self._register(r)
            self._count("admitted")
        self.slot_rid[s] = r.rid
        self.slot_prompt[s] = np.asarray(r.tokens, np.int32)
        self.lengths[s] = start
        self.active[s] = False
        self.prefilling[s] = True
        self.budget[s] = r.max_new
        self._drafter_reset(s)
        self._count("prompt_tokens", len(r.tokens))
        self._count("prefill_tokens", len(r.tokens) - start)
        self._count_converts(len(r.tokens) - start)

    def admit(self, reqs: list[Request], now: float) -> None:
        """Admit ``reqs`` into free slots — host bookkeeping only: per-slot
        prompt/offset state, page allocation + prefix-cache matching
        (paged), and a fresh zero row + encoder memory for recurrent-state
        families.  The prompts are then fed through chunked
        ``_prefill_step`` calls interleaved with decode bursts; a row whose
        request completes on its first token (EOS or ``max_new == 1``)
        frees its slot at that point."""
        if not reqs:
            return
        with self.obs.tracer.span("admit", n=len(reqs),
                                  rids=[r.rid for r in reqs]):
            free = [s for s in range(self.scfg.n_slots)
                    if self.slot_rid[s] is None]
            assert len(reqs) <= len(free), \
                "admitting more requests than slots"
            if self.paged:
                self._admit_paged(reqs, free)
            else:
                self._admit_dense(reqs, free)

    def _admit_dense(self, reqs, free):
        scfg = self.scfg
        n = scfg.n_slots
        if self._needs_reset:
            # SSM/hybrid/encdec chunk-prefill through gated decode steps,
            # which CONTINUE from the slot's stored recurrent state — wipe
            # the admitted rows (and install encoder memory) before the
            # first chunk.  Attention rows skip this: the kv_index <=
            # position mask already hides a previous occupant's stale KV.
            fresh = self.model.init_cache(self.params, n, scfg.max_len,
                                          scfg.cache_dtype)
            if reqs[0].frames is not None:
                if any(r.frames is None for r in reqs):
                    raise ValueError("mixed group: some requests carry "
                                     "encoder frames and some do not")
                g = _bucket(len(reqs), lo=1)
                fr = np.stack([np.asarray(r.frames) for r in reqs])
                fr = np.concatenate(
                    [fr, np.repeat(fr[:1], g - len(reqs), 0)], 0)
                mem = np.asarray(self._encode(self.params, jnp.asarray(fr)))
                memp = np.array(fresh["memory"])
                memp[:len(reqs)] = mem[:len(reqs)].astype(memp.dtype)
                fresh = dict(fresh, memory=jnp.asarray(memp))
            # row j -> slot free[j]; pad both index vectors to n_slots by
            # repeating the LAST pair (duplicate writes of identical rows
            # are benign) so the jitted scatter compiles exactly once
            order = np.arange(n, dtype=np.int32)
            order[len(reqs):] = len(reqs) - 1
            slots = np.array(free[:len(reqs)]
                             + [free[len(reqs) - 1]] * (n - len(reqs)),
                             np.int32)
            picked = jax.tree.map(
                lambda leaf, ax: jnp.take(leaf, jnp.asarray(order), axis=ax),
                fresh, self._axes)
            self.cache = self._scatter(self.cache, picked,
                                       jnp.asarray(slots))
        for j, r in enumerate(reqs):
            self._start_prefill(free[j], r, 0)

    # -- paged admission (page allocation + prefix cache) --------------

    def _alloc_pages(self, n: int) -> Optional[list]:
        """``n`` pages from the pool, evicting prefix-cache LRU leaves on
        shortage.  None when the demand cannot be met even after eviction
        (the caller requeues or preempts)."""
        if n <= 0:
            return []
        pages = self.pool.alloc(n)
        if pages is None and self.trie is not None:
            with self.obs.tracer.span("evict",
                                      short=n - self.pool.free_pages):
                self.trie.evict(n - self.pool.free_pages)
            pages = self.pool.alloc(n)
        return pages

    def _drafter_reset(self, s: int) -> None:
        """A slot changed owner: wipe any drafter state tied to it (the
        model drafter's synced-length watermark; the n-gram drafter is
        stateless)."""
        if self.drafter is not None:
            self.drafter.reset_slot(s)

    def _release_slot_pages(self, s: int) -> None:
        for p in self.slot_pages[s]:
            self.pool.decref(p)
        self.slot_pages[s] = []
        self.block_tables[s, :] = 0

    def _admit_paged(self, reqs, free):
        """Paged admission: allocate each prompt's pages (reusing cached
        prefix pages when the radix trie matches) and install the block
        table — no model call here.  Cold rows start their chunked prefill
        at offset 0, hit rows at the matched length: the cached tokens
        never touch the model, and the un-cached suffix flows through the
        same ``prefill_chunk`` calls as everything else.
        """
        ps = self.scfg.page_size
        plans, leftover = [], []
        for i, r in enumerate(reqs):
            toks = np.asarray(r.tokens, np.int32)
            matched_pages: list = []
            matched = 0
            if self.trie is not None:
                # match on tokens[:-1]: at least one suffix token always
                # remains to produce the first generated token's logits
                matched_pages, matched = self.trie.match(toks[:-1].tolist())
                for p in matched_pages:
                    # pin BEFORE _alloc_pages: its trie eviction would
                    # otherwise free the just-matched (trie-only) pages and
                    # could hand them straight back as this request's tail
                    self.pool.incref(p)
            need = -(-len(toks) // ps)
            new = self._alloc_pages(need - len(matched_pages))
            if new is None and matched_pages:
                # under pressure the pinned prefix may be the only memory
                # left: drop the match and admit cold, letting eviction
                # reclaim it (correct, just uncached)
                for p in matched_pages:
                    self.pool.decref(p)
                matched_pages, matched = [], 0
                new = self._alloc_pages(need)
            if new is None:  # page exhaustion: try again after some free up
                leftover = reqs[i:]
                break
            plans.append((r, matched, list(matched_pages) + new))
        if leftover:
            self._queue.extendleft(reversed(leftover))
        for j, (r, matched, pages) in enumerate(plans):
            s = free[j]
            self._start_prefill(s, r, matched)
            # prefill_chunk writes through the block table: install it (and
            # the page ownership) before the first chunk runs
            self.slot_pages[s] = list(pages)
            self.block_tables[s, :] = 0
            self.block_tables[s, :len(pages)] = pages
            self._count("cached_tokens", matched)
            if matched:
                self._count("prefix_hits")
        self._peak("pages_peak", self.pool.pages_in_use)

    # -- chunked prefill ------------------------------------------------

    def _prefill_step(self, now: float) -> None:
        """Feed every prefilling slot its next chunk through ONE
        ``engine.build_prefill_chunk`` call (packed; ``pack_prefill=False``
        feeds only the earliest-arrival slot — an ablation knob).  The call
        width is ``prefill_chunk`` (0 = whole remaining prompt) capped to
        the bucketed longest remainder; rows that finish inside this chunk
        take their first generated token from the returned last-lane logits
        and flip to ``active`` (or free immediately on EOS / budget 1)."""
        scfg = self.scfg
        n = scfg.n_slots
        rows = [s for s in range(n) if self.prefilling[s]]
        if not rows:
            return
        if not scfg.pack_prefill:
            rows = [min(rows, key=lambda s: (
                self.requests[self.slot_rid[s]].arrival, self.slot_rid[s]))]
        rem = {s: len(self.slot_prompt[s]) - int(self.lengths[s])
               for s in rows}
        cap = min(_bucket(max(rem.values())), scfg.max_len)
        width = min(scfg.prefill_chunk, cap) if scfg.prefill_chunk > 0 \
            else cap
        toks = np.zeros((n, width), np.int32)
        n_valid = np.ones(n, np.int32)
        gate = np.zeros(n, bool)
        for s in rows:
            part = self.slot_prompt[s][int(self.lengths[s]):
                                       int(self.lengths[s]) + width]
            toks[s, :len(part)] = part
            n_valid[s] = len(part)
            gate[s] = True
        if self.paged:
            self.cache["block_tables"] = jnp.asarray(self.block_tables)
        with self.obs.tracer.span("prefill_chunk", width=width,
                                  rows=len(rows)):
            pc = engine.build_prefill_chunk(self.model,
                                            _burst_key_cfg(scfg), width)
            # jnp.asarray copies the host mirror, so mutating self.lengths
            # below cannot race the dispatched call
            t_in = time.perf_counter()
            last, self.cache = pc(self.params, self.cache,
                                  jnp.asarray(toks),
                                  jnp.asarray(self.lengths),
                                  jnp.asarray(n_valid), jnp.asarray(gate))
            exe = f"prefill_chunk[w={width}]"
            if exe in self.obs.profile:  # cost join needs the real wall
                jax.block_until_ready(last)
                self.obs.profile.observe(exe, time.perf_counter() - t_in)
        self._count("prefills")
        for s in rows:
            self.lengths[s] += min(rem[s], width)
        # numeric health: every gated row's last-lane logits must be finite
        # — a poisoned KV page / fp2fx8 scale row surfaces here before the
        # slot ever decodes, and the quarantine ladder takes it
        finite = np.asarray(jnp.isfinite(last).all(-1))
        bad = [s for s in rows if not finite[s]]
        for s in bad:
            self._quarantine(s, now, where="prefill")
        fin = [s for s in rows if rem[s] <= width and s not in bad]
        if fin:
            tok0 = np.asarray(self._first_token(last), np.int32)
            for s in fin:
                self._finish_prefill(s, int(tok0[s]), now)
        self._peak("peak_active", int(self.active.sum()))
        self._audit_check()

    def _finish_prefill(self, s: int, tok0: int, now: float) -> None:
        """Slot ``s``'s whole prompt is cached and its first generated
        token is in hand: publish the prompt's full pages to the prefix
        cache, emit the token, and either activate the slot for decode or
        free it (EOS / budget exhausted on the very first token)."""
        self.prefilling[s] = False
        rid = self.slot_rid[s]
        if self.trie is not None:
            # publish the admitted prompt's FULL pages (partial tail pages
            # are never shared — decode writes into them); insert before
            # any done-row release so adopted pages survive it
            ptoks = self.slot_prompt[s]
            nfull = len(ptoks) // self.scfg.page_size
            if nfull:
                self.trie.insert(
                    [int(t) for t in ptoks[:nfull * self.scfg.page_size]],
                    self.slot_pages[s][:nfull])
            self._peak("pages_peak", self.pool.pages_in_use)
        self.outputs[rid].append(tok0)
        self.out_times[rid].append(now)
        self._count("tokens_emitted")
        done = (self.budget[s] <= 1
                or (self._eos is not None and tok0 == self._eos))
        if done:
            self._finish(rid, now)
            self.slot_rid[s] = None
            self.slot_prompt[s] = None
            if self.paged:
                self._release_slot_pages(s)
            return
        self.budget[s] -= 1
        self.last_tok[s] = tok0
        self.active[s] = True

    def _now(self) -> float:
        """Seconds since run() started (0 before/outside a run)."""
        return time.perf_counter() - self._t0 if self._t0 is not None else 0.0

    def _free_slot(self, s: int) -> None:
        """Detach slot ``s`` from its request and return its resources."""
        self.active[s] = False
        self.prefilling[s] = False
        self.slot_rid[s] = None
        self.slot_prompt[s] = None
        if self.paged:
            self._release_slot_pages(s)

    def _fail(self, rid: int, reason: str, now: float,
              detail: str = "") -> None:
        """Terminate ``rid`` with a structured failure — the partial tokens
        generated so far stay on the Completion (DESIGN.md §13)."""
        r = self.requests[rid]
        self.completions[rid] = Completion(
            rid=rid, tokens=self.outputs.get(rid, []),
            prompt_len=len(r.tokens), finished_at=now, arrival=r.arrival,
            token_times=list(self.out_times.get(rid, [])),
            failure=FailureInfo(reason=reason, detail=detail,
                                retries=self.retries.get(rid, 0)))
        self._count("failures")
        self._record_completion(self.completions[rid])

    def _requeue(self, s: int, now: float) -> bool:
        """Push slot ``s``'s request back to the queue FRONT with the
        tokens generated so far folded into the prompt (the preemption /
        quarantine resume path — greedy continuation is token-for-token
        identical).  Bounded: a request requeued more than ``max_retries``
        times fails structurally instead, converting pressure livelock
        into a definite outcome.  The slot itself is NOT freed here."""
        rid = self.slot_rid[s]
        nret = self.retries.get(rid, 0) + 1
        self.retries[rid] = nret
        if nret > self.scfg.max_retries:
            self._fail(rid, "retries_exhausted", now,
                       detail=f"requeued {nret} times")
            return False
        orig = self.requests[rid]
        done = self.outputs[rid]
        toks = np.concatenate([np.asarray(orig.tokens, np.int32),
                               np.asarray(done, np.int32)])
        # remaining budget from the HOST trace, not the device budget
        # mirror: a quarantined slot's garbage steps already burned device
        # budget the request never received tokens for
        self._queue.appendleft(Request(
            rid=rid, tokens=toks, max_new=orig.max_new - len(done),
            frames=orig.frames, arrival=orig.arrival,
            deadline=orig.deadline, resume=True))
        return True

    def _preempt_latest(self) -> bool:
        """Page exhaustion mid-decode: free the latest-arrival occupied
        slot (ties by rid) — decoding or mid-prefill — and requeue its
        request through the normal admission path with the tokens generated
        so far folded into the prompt (greedy continuation is
        token-for-token identical); a request past its retry budget fails
        structurally instead.  Returns True if a slot was freed."""
        cands = [s for s in range(self.scfg.n_slots)
                 if self.active[s] or self.prefilling[s]]
        if not cands:
            return False
        s = max(cands, key=lambda c: (self.requests[self.slot_rid[c]].arrival,
                                      self.slot_rid[c]))
        self.obs.tracer.instant("preempt", rid=self.slot_rid[s], slot=s)
        self._requeue(s, self._now())
        self._free_slot(s)
        self._count("preemptions")
        self._audit_check()
        return True

    def _ensure_burst_pages(self, steps: int) -> None:
        """Grow every active slot's block table to cover its next ``steps``
        decode writes.  Exhaustion evicts prefix-cache LRU pages first
        (inside ``_alloc_pages``), then preempts the latest-arrival slot
        and retries — the freed pages unblock the rest of the pool."""
        while True:
            short = False
            for s in range(self.scfg.n_slots):
                if not self.active[s]:
                    continue
                horizon = int(self.lengths[s]) + min(steps,
                                                     int(self.budget[s]))
                nb_need = min(-(-horizon // self.scfg.page_size),
                              self.n_blocks)
                have = len(self.slot_pages[s])
                new = self._alloc_pages(nb_need - have)
                if new is None:
                    short = True
                    break
                if new:
                    self.block_tables[s, have:have + len(new)] = new
                    self.slot_pages[s].extend(new)
            if not short:
                self._peak("pages_peak", self.pool.pages_in_use)
                return
            if not self._preempt_latest():
                return

    def _finish(self, rid: int, now: float) -> None:
        r = self.requests[rid]
        self.completions[rid] = Completion(
            rid=rid, tokens=self.outputs[rid], prompt_len=len(r.tokens),
            finished_at=now, arrival=r.arrival,
            token_times=list(self.out_times[rid]))
        self._record_completion(self.completions[rid])

    # -- decode --------------------------------------------------------

    def _ttl_vector(self, now: float) -> np.ndarray:
        """Per-slot decode-step allowance derived from wall-clock deadlines:
        with a warm per-step time estimate (the straggler monitor's EMA), a
        deadlined slot gets ``floor(remaining / est)`` steps so the burst
        cannot overrun its deadline by up to ``decode_burst`` tokens (min 1
        — the host-side ``_expire`` sweep catches the already-late case
        before the burst); without an estimate, ``TTL_NONE`` and the host
        expires between bursts."""
        n = self.scfg.n_slots
        ttl = np.full(n, TTL_NONE, np.int32)
        if self._step_ema <= 0:
            return ttl
        for s in range(n):
            rid = self.slot_rid[s]
            if rid is None or not self.active[s]:
                continue
            d = self.requests[rid].deadline
            if d is not None:
                ttl[s] = int(np.clip((d - now) / self._step_ema, 1,
                                     TTL_NONE))
        return ttl

    def _observe_burst(self, dt: float, steps: int) -> None:
        """Feed the burst wall time to the straggler monitor (outlier
        bursts are flagged, not folded into the EMA) and refresh the
        per-step estimate the deadline TTL uses."""
        self._hists["burst_wall_s"].observe(dt)
        if self.straggler.observe(dt):
            self._count("stragglers")
        if self.straggler.ema > 0 and steps > 0:
            self._step_ema = self.straggler.ema / steps

    def _expire_slot(self, s: int, now: float) -> None:
        """Slot ``s``'s request passed its deadline: structured ``deadline``
        failure with the tokens generated so far; slot + pages freed."""
        rid = self.slot_rid[s]
        d = self.requests[rid].deadline
        self.obs.tracer.instant("expire", rid=rid, slot=s)
        self._fail(rid, "deadline", now, detail=f"deadline {d:.3f}s")
        self._free_slot(s)
        self._count("expired")

    def burst(self, now: float) -> None:
        """One jitted burst of ``decode_burst`` masked steps + host
        bookkeeping: append emitted tokens, finalize newly freed slots.
        Paged mode first appends the pages the burst will write (possibly
        preempting) and refreshes the device block tables.  In spec mode
        the burst is ONE speculative step: draft, verify, accept, roll
        back.  Robustness (DESIGN.md §13): deadlined slots carry a TTL the
        device decrements alongside budget; per-step finite flags come back
        with the tokens, and a slot whose logits went non-finite keeps only
        its finite-prefix tokens and is quarantined."""
        if self.chaos is not None:
            self.chaos.fire(self, "pre_burst")
        if self.spec:
            self._spec_burst(now)
            return
        if self.paged:
            self._ensure_burst_pages(max(1, self.scfg.decode_burst))
            if not self.active.any():  # everyone preempted: nothing to run
                return
            self.cache["block_tables"] = jnp.asarray(self.block_tables)
        was_active = self.active.copy()
        with self.obs.tracer.span("decode_burst",
                                  active=int(self.active.sum())):
            t_in = time.perf_counter()
            emits, oks, self.cache, tok, lengths, active, budget, ttl_out, \
                self.key, tstats = self._burst(
                    self.params, self.cache,
                    jnp.asarray(self.last_tok)[:, None],
                    jnp.asarray(self.lengths),
                    jnp.asarray(self.active),
                    jnp.asarray(self.budget),
                    jnp.asarray(self._ttl_vector(now)), self.key)
            emits = np.asarray(emits)                   # (steps, n_slots)
            oks = np.asarray(oks)                       # (steps, n_slots)
            ttl_out = np.asarray(ttl_out)
            # np.array (not asarray): jax exports read-only views, but
            # admission writes per-slot entries into these host mirrors
            self.lengths = np.array(lengths)
            self.active = np.array(active)
            self.budget = np.array(budget)
            self.last_tok = np.array(tok)[:, 0]
            dt = time.perf_counter() - t_in  # np.asarray blocked above
            self._observe_burst(dt, emits.shape[0])
            self.obs.profile.observe("decode_burst", dt)
        if tstats:
            self.obs.numerics.update(tstats)
        self._count("bursts")
        self._count("burst_steps", emits.shape[0])
        self._count("model_calls", emits.shape[0])
        n_active_steps = int((emits != PAD).sum())
        self._count("slot_steps_active", n_active_steps)
        self._count_converts(n_active_steps)
        for s in np.nonzero(was_active)[0]:
            col = emits[:, s]
            bad = np.nonzero(~oks[:, s])[0]
            # keep only the finite-prefix tokens: the first non-finite
            # step's sample (and everything after) is garbage
            col = col[:int(bad[0])] if bad.size else col
            toks = col[col != PAD].tolist()
            rid = self.slot_rid[s]
            self.outputs[rid].extend(toks)
            self.out_times[rid].extend([now] * len(toks))
            self._count("tokens_emitted", len(toks))
            if bad.size:
                self._quarantine(s, now, where="burst")
                continue
            if not self.active[s]:                      # freed on device
                hit_eos = (self._eos is not None and toks
                           and toks[-1] == self._eos)
                if ttl_out[s] <= 0 and self.budget[s] > 0 and not hit_eos:
                    self._expire_slot(s, now)           # deadline TTL
                else:
                    self._finish(rid, now)
                    self._free_slot(s)
        self._audit_check()

    # -- speculative decode (repro/serve/spec.py; DESIGN.md §11) --------

    def _spec_burst(self, now: float) -> None:
        """One speculative step over the whole pool: host-side drafting
        (per-slot ragged lengths), ONE jitted verify call scoring
        ``draft_k + 1`` lanes per slot, longest-accepted-prefix emission
        with EOS/budget on accepted tokens only, then KV rollback — dense
        slots rewind by length alone; paged slots also un-append the tail
        pages the rejected lanes wrote into."""
        scfg = self.scfg
        K = scfg.draft_k
        if self.paged:
            # verify writes lanes L..L+m (m <= min(K, budget-1)): cover the
            # worst case before the call, preempting on pool exhaustion
            self._ensure_burst_pages(K + 1)
            if not self.active.any():  # everyone preempted: nothing to run
                return
            self.cache["block_tables"] = jnp.asarray(self.block_tables)
        n = scfg.n_slots
        want = np.zeros(n, np.int32)
        contexts: list = [None] * n
        for s in range(n):
            if not self.active[s]:
                continue
            # drafts past budget-1 can never be emitted, and the verify
            # write frontier must stay inside max_len
            want[s] = max(0, min(K, int(self.budget[s]) - 1,
                                 scfg.max_len - 1 - int(self.lengths[s])))
            rid = self.slot_rid[s]
            contexts[s] = np.concatenate(
                [np.asarray(self.requests[rid].tokens, np.int32),
                 np.asarray(self.outputs[rid], np.int32)])
        calls0 = self.drafter.model_calls
        draft, n_draft = self.drafter.draft_batch(contexts, want, K)
        # a model drafter's teacher-sync/draft-loop invocations count too,
        # so tokens-per-model-call never overstates the amortization
        self._count("model_calls", self.drafter.model_calls - calls0)
        if self.chaos is not None:
            # drafter-desync fault: junk drafts are REJECTED by exact
            # verification, so outputs are provably unchanged
            draft, n_draft = self.chaos.corrupt_drafts(self, draft, n_draft,
                                                       want)

        was_active = self.active.copy()
        with self.obs.tracer.span("spec_verify",
                                  active=int(self.active.sum())):
            t_in = time.perf_counter()
            emitted, self.cache, tok, lengths, active, budget, n_acc, ok, \
                tstats = self._spec_step(
                    self.params, self.cache,
                    jnp.asarray(self.last_tok)[:, None],
                    jnp.asarray(draft), jnp.asarray(n_draft),
                    jnp.asarray(self.lengths),
                    jnp.asarray(self.active),
                    jnp.asarray(self.budget))
            emitted = np.asarray(emitted)               # (n_slots, K + 1)
            n_acc = np.asarray(n_acc)
            ok = np.asarray(ok)                         # per-slot finite bit
            self.lengths = np.array(lengths)
            self.active = np.array(active)
            self.budget = np.array(budget)
            self.last_tok = np.array(tok)[:, 0]
            dt = time.perf_counter() - t_in
            self._observe_burst(dt, 1)
            self.obs.profile.observe("spec_step", dt)
        if tstats:
            self.obs.numerics.update(tstats)
        self._count("bursts")
        self._count("burst_steps")
        self._count("spec_steps")
        self._count("model_calls")
        for s in np.nonzero(was_active)[0]:
            if not ok[s]:
                # non-finite verify logits poison every lane's argmax: no
                # token from this step can be trusted, so emit nothing and
                # quarantine (the finite prefix already in outputs stands)
                self._quarantine(s, now, where="spec")
                continue
            row = emitted[s]
            row = row[row != PAD].tolist()
            self.outputs[self.slot_rid[s]].extend(row)
            self.out_times[self.slot_rid[s]].extend([now] * len(row))
            self._count("tokens_emitted", len(row))
            self._count("draft_tokens", int(n_draft[s]))
            self._count("accepted_tokens", int(n_acc[s]))
            self._count_converts(len(row))
            if row:
                self._count("slot_steps_active")
            if not self.active[s]:                      # freed on device
                self._finish(self.slot_rid[s], now)
                self._free_slot(s)
        if self.paged:
            self._rollback_spec_pages()
        self._audit_check()

    def _rollback_spec_pages(self) -> None:
        """Un-append tail pages past each active slot's post-acceptance
        length — the rejected verify lanes' pages.  Refcount-correct by
        construction: only pages popped off the slot's OWN table are
        decref'd, so a page the radix trie also references survives at the
        trie's count; and since lengths never shrink, the keep point can
        never reach back into the prompt's (possibly trie-shared) pages —
        only ever into this burst's fresh appends."""
        ps = self.scfg.page_size
        for s in range(self.scfg.n_slots):
            if not self.active[s]:
                continue
            keep = -(-int(self.lengths[s]) // ps)
            while len(self.slot_pages[s]) > keep:
                p = self.slot_pages[s].pop()
                self.block_tables[s, len(self.slot_pages[s])] = 0
                self.pool.decref(p)

    # -- robustness: quarantine, scrub, degradation ladder (§13) --------

    def _scrub_dense_slot(self, s: int) -> None:
        """Overwrite slot ``s``'s dense cache rows with freshly initialized
        ones — stale NaN/Inf KV would otherwise poison the slot's NEXT
        occupant through the ``0 * NaN = NaN`` path of masked attention
        (scores are masked with NEG_BIG, but a non-finite V row still
        reaches the ``probs @ v`` contraction)."""
        scfg = self.scfg
        n = scfg.n_slots
        if self._scatter is None:
            self._axes = _cache_batch_axes(self.model, self.params,
                                           scfg.max_len, scfg.cache_dtype)
            self._scatter = build_scatter(self.model, self._axes,
                                          scfg.max_len, scfg.cache_dtype)
        fresh = self.model.init_cache(self.params, n, scfg.max_len,
                                      scfg.cache_dtype)
        self.cache = self._scatter(self.cache, fresh,
                                   jnp.full(n, s, dtype=I32))

    def _scrub_slot_pages(self, s: int) -> None:
        """Zero slot ``s``'s EXCLUSIVE pages (refcount 1) before they go
        back to the pool, so a poisoned row cannot leak to the page's next
        owner.  Trie-shared prompt pages (refcount > 1) are read-only
        replays of clean prefill writes and stay — zeroing them would
        corrupt other requests' cached prefixes."""
        pages = [p for p in self.slot_pages[s] if self.pool.refs[p] == 1]
        if not pages:
            return
        if self._zero_pages is None:
            @functools.partial(jax.jit, donate_argnums=(0,))
            def zp(blocks, idx):
                return jax.tree.map(
                    lambda lf: lf.at[:, idx].set(jnp.zeros((), lf.dtype)),
                    blocks)
            self._zero_pages = zp
        # pad to n_blocks with the null page: one compilation, and writes
        # at page 0 land in the never-read sink
        idx = np.full(self.n_blocks, kvpool.NULL_PAGE, np.int32)
        idx[:len(pages)] = pages
        self.cache["blocks"] = self._zero_pages(self.cache["blocks"],
                                                jnp.asarray(idx))

    def _quarantine(self, s: int, now: float, where: str = "") -> None:
        """Slot ``s`` produced non-finite logits: scrub its KV, free it,
        and walk the degradation ladder (DESIGN.md §13) — first fault:
        requeue and recompute from the prompt + finite-prefix tokens
        (greedy outputs unchanged); repeat fault: ONE retry on the unfused
        fp32 dense path; still faulting: structured ``numeric_fault``.
        Exactly the silent-corruption shape fp2fx conversion invites —
        ``core/numerics.py`` saturates ±inf and maps NaN -> 0, so a bad
        scale row degrades accuracy silently while the logits go bad
        loudly; this is where the loud signal is caught."""
        rid = self.slot_rid[s]
        nf = self.numeric_faults.get(rid, 0) + 1
        self.numeric_faults[rid] = nf
        self._count("quarantines")
        # annotate the decision with the numeric stats that triggered
        # it (the last telemetry burst's exponent/scale readings)
        ev = self.obs.numerics.record_quarantine(rid, where or "burst")
        self.obs.tracer.instant("quarantine", slot=s, fault=nf, **ev)
        if self.paged:
            self._scrub_slot_pages(s)
        else:
            self._scrub_dense_slot(s)
        if nf == 1:
            self._requeue(s, now)  # may fail structurally on the retry cap
        elif nf == 2 and self._allow_fp32_retry:
            self._fp32_retry(rid, now)
        else:
            self._fail(rid, "numeric_fault", now,
                       detail=f"non-finite logits at {where} (fault {nf})")
        self._free_slot(s)
        self._audit_check()

    def _fp32_retry(self, rid: int, now: float) -> None:
        """Second numeric fault for ``rid``: re-run it solo on the unfused
        fp32 dense path — a fresh engine, fresh cache, no prefix sharing,
        no chaos — continuing from the finite-prefix tokens already
        emitted.  A clean retry completes the request (greedy outputs
        identical to a fault-free run); a retry that faults again surfaces
        a structured ``numeric_fault``."""
        self._count("fp32_retries")
        orig = self.requests[rid]
        done = list(self.outputs[rid])
        sched = ("continuous"
                 if self.scfg.scheduler in ("continuous", "spec")
                 else "lockstep")
        sub = dataclasses.replace(
            self.scfg, cache_dtype="float32", attn_mode="unfused",
            kv_layout="dense", prefix_cache=False, n_slots=1,
            scheduler=sched, audit=False, max_queue=0, n_pages=0)
        eng = SlotPoolEngine(self.model, self.params, sub)
        eng._allow_fp32_retry = False   # the fallback never recurses
        toks = np.concatenate([np.asarray(orig.tokens, np.int32),
                               np.asarray(done, np.int32)])
        rem = (orig.deadline - now) if orig.deadline is not None else None
        comp = eng.run([Request(rid=rid, tokens=toks,
                                max_new=orig.max_new - len(done),
                                frames=orig.frames, deadline=rem)])[rid]
        fin = self._now()
        self.outputs[rid].extend(comp.tokens)
        self.out_times[rid].extend([fin] * len(comp.tokens))
        if comp.failure is None:
            self._finish(rid, fin)
        else:
            reason = ("deadline" if comp.failure.reason == "deadline"
                      else "numeric_fault")
            self._fail(rid, reason, fin,
                       detail=f"fp32 retry: {comp.failure.reason}")

    # -- robustness: cancellation, deadlines, shutdown, audits (§13) ----

    def cancel(self, rid: int) -> None:
        """Request host-side cancellation of ``rid``: honored at the next
        scheduling checkpoint (between bursts), emitting a partial
        Completion with ``cancelled=True``."""
        self._cancels.add(rid)

    def _cancel_done(self, rid: int, now: float) -> None:
        r = self.requests[rid]
        self.completions[rid] = Completion(
            rid=rid, tokens=self.outputs.get(rid, []),
            prompt_len=len(r.tokens), finished_at=now, arrival=r.arrival,
            token_times=list(self.out_times.get(rid, [])), cancelled=True)
        self._count("cancelled")
        self._record_completion(self.completions[rid])

    def _apply_cancels(self, now: float) -> None:
        if not self._cancels:
            return
        todo, self._cancels = self._cancels, set()
        for rid in todo:
            if rid in self.completions or rid not in self.requests:
                continue  # already terminal / never submitted
            for s in range(self.scfg.n_slots):
                if self.slot_rid[s] == rid:
                    self._free_slot(s)
                    break
            self._queue = deque(r for r in self._queue if r.rid != rid)
            self._pending = deque(r for r in self._pending if r.rid != rid)
            self._cancel_done(rid, now)
        self._audit_check()

    def _expire(self, now: float) -> None:
        """Host-side deadline sweep over slots and the waiting queue.  The
        device TTL bounds mid-burst overrun; this sweep guarantees an
        already-late request is expired at the next scheduling checkpoint
        even when the step-time estimate is cold."""
        for s in range(self.scfg.n_slots):
            rid = self.slot_rid[s]
            if rid is None:
                continue
            d = self.requests[rid].deadline
            if d is not None and now >= d:
                self._expire_slot(s, now)
        late = [r for r in self._queue
                if r.deadline is not None and now >= r.deadline]
        if late:
            gone = {r.rid for r in late}
            self._queue = deque(r for r in self._queue if r.rid not in gone)
            for r in late:
                self._register(r)
                self._fail(r.rid, "deadline", now, detail="expired in queue")
                self._count("expired")
        self._audit_check()

    def shutdown(self) -> dict[int, Completion]:
        """Drain: every in-flight or queued request without a completion is
        terminated as cancelled with its partial tokens, and all slots and
        pages are freed — the graceful KeyboardInterrupt path
        (launch/serve.py, examples/serve_decode.py).  Idempotent; returns
        the completions map."""
        now = self._now()
        for s in range(self.scfg.n_slots):
            rid = self.slot_rid[s]
            if rid is not None:
                self._free_slot(s)
                if rid not in self.completions:
                    self._cancel_done(rid, now)
        for r in list(self._queue) + list(self._pending):
            if r.rid not in self.completions:
                self._register(r)
                self._cancel_done(r.rid, now)
        self._queue.clear()
        self._pending.clear()
        self._audit_check()
        return self.completions

    def _audit_check(self) -> None:
        """Recompute pool/trie refcounts from live slots + trie edges and
        cross-check the free list (``kvpool.PagePool.audit``).  Called at
        every admission / finish / preemption / quarantine / expiry
        checkpoint when ``ServeConfig.audit`` is on, so bookkeeping drift
        surfaces AT the mutation that caused it, not requests later.  The
        chaos harness's squeezed pages ride along as extra holders."""
        if not self.scfg.audit or not self.paged:
            return
        self._count("audits")
        for s in range(self.scfg.n_slots):
            if self.slot_rid[s] is None and self.slot_pages[s]:
                raise kvpool.AuditError(
                    f"freed slot {s} still holds pages {self.slot_pages[s]}")
        self.pool.audit(list(self.slot_pages) + list(self._extra_holders),
                        self.trie)

    # -- the serving loop ----------------------------------------------

    def run(self, requests: list[Request]) -> dict[int, Completion]:
        """Serve ``requests`` (sorted by ``arrival``) until every one has a
        DEFINITE outcome — finished, cancelled, or structured failure
        (DESIGN.md §13).  Malformed requests fail individually with reason
        ``invalid`` instead of aborting the whole batch.

        With the tracer enabled, the whole run is under a compile watch: a
        mid-flight XLA compile (a retrace the prewarm missed) shows up as a
        backdated "compile" span in the trace (DESIGN.md §15)."""
        tracer = self.obs.tracer
        with compile_watch(tracer, enabled=tracer.enabled):
            return self._run(requests)

    def _run(self, requests: list[Request]) -> dict[int, Completion]:
        ok_reqs = []
        for r in sorted(requests, key=lambda r: r.arrival):
            self._register(r)
            if r.max_new < 1:
                self._fail(r.rid, "invalid", 0.0,
                           detail=f"max_new {r.max_new} < 1")
            elif len(r.tokens) + r.max_new > self.scfg.max_len:
                self._fail(r.rid, "invalid", 0.0,
                           detail=f"prompt {len(r.tokens)} + max_new "
                                  f"{r.max_new} exceeds max_len "
                                  f"{self.scfg.max_len}")
            else:
                ok_reqs.append(r)
        self._pending = deque(ok_reqs)
        self._queue = deque()
        self._t0 = t0 = time.perf_counter()
        continuous = self.scfg.scheduler in ("continuous", "spec")
        while (self._pending or self._queue or self.active.any()
               or self.prefilling.any()):
            now = time.perf_counter() - t0
            if self.chaos is not None:
                self.chaos.fire(self, "tick")
            self._apply_cancels(now)
            self._expire(now)
            # arrivals move into the BOUNDED waiting queue: admission
            # backpressure rejects (reason "queue_full") instead of letting
            # the queue grow without limit; requeues from preemption /
            # quarantine bypass this — they already held an admission
            while self._pending and self._pending[0].arrival <= now:
                r = self._pending.popleft()
                if (self.scfg.max_queue
                        and len(self._queue) >= self.scfg.max_queue):
                    self._fail(r.rid, "queue_full", now,
                               detail=f"{len(self._queue)} waiting")
                    self._count("rejected")
                else:
                    self._queue.append(r)
            free = sum(1 for rid in self.slot_rid if rid is None)
            busy = self.active.any() or self.prefilling.any()
            can_admit = continuous or not busy
            batch = []
            while can_admit and self._queue and len(batch) < free:
                batch.append(self._queue.popleft())
            if batch:
                # page-starved admissions requeue their tail to the front
                self.admit(batch, time.perf_counter() - t0)
                self._audit_check()
            # per-iteration load gauges + periodic metrics snapshot export
            self._gauges["queue_depth"].set(len(self._queue))
            self._gauges["slot_occupancy"].set(
                sum(1 for rid in self.slot_rid if rid is not None))
            if self.paged:
                self._gauges["pages_in_use"].set(self.pool.pages_in_use)
            self.obs.maybe_snapshot()
            if self.prefilling.any():
                # at most ONE chunk per loop iteration: a long prompt's
                # prefill interleaves with the decode bursts below instead
                # of stalling them for the whole prompt
                self._prefill_step(time.perf_counter() - t0)
            if self.active.any():
                self.burst(time.perf_counter() - t0)
            elif (not self.prefilling.any() and not self._queue
                    and self._pending):
                # idle: wait for the next arrival
                now = time.perf_counter() - t0
                time.sleep(max(0.0, min(
                    self._pending[0].arrival - now, 0.01)))
        self.obs.maybe_snapshot(force=True)
        return self.completions


def serve(model, params, requests: list[Request], scfg: ServeConfig,
          key=None, draft=None, chaos=None) -> dict[int, Completion]:
    """One-shot entry: build a slot-pool engine, serve, return completions.
    ``draft``: optional (model, params) pair for ``spec_mode="model"``;
    ``chaos``: optional ``repro.serve.chaos.ChaosMonkey`` fault injector."""
    eng = SlotPoolEngine(model, params, scfg, key=key, draft=draft,
                         chaos=chaos)
    eng.run(requests)
    return eng.completions
