"""Serving engine: batched prefill + greedy/temperature decode."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ServeConfig

I32 = jnp.int32


def build_serve_step(model, scfg: ServeConfig):
    """Returns jit'd (params, cache, tokens1, pos) -> (next_token, cache)."""
    @functools.partial(jax.jit, static_argnames=())
    def step(params, cache, tokens1, pos, key):
        logits, cache = model.decode_step(params, cache, tokens1, pos)
        logits = logits[:, -1, :]
        if scfg.temperature > 0:
            nxt = jax.random.categorical(key, logits / scfg.temperature, -1)
        else:
            nxt = jnp.argmax(logits, -1)
        return nxt.astype(I32)[:, None], cache
    return step


def generate(model, params, batch: dict, scfg: ServeConfig, max_new: int,
             key=None):
    """Prefill the prompt then decode ``max_new`` tokens. Returns (B, max_new)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    from repro.models import resolve_attn_mode
    model = resolve_attn_mode(model, scfg.attn_mode)
    B = batch["tokens"].shape[0]
    cache = model.init_cache(params, B, scfg.max_len, jnp.dtype(scfg.cache_dtype))
    logits, cache, pos = model.prefill(params, cache, batch)
    last = logits[:, -1, :] if logits.ndim == 3 else logits
    tok = jnp.argmax(last, -1).astype(I32)[:, None]
    out = [tok]
    step = build_serve_step(model, scfg)
    for i in range(max_new - 1):
        key, sub = jax.random.split(key)
        tok, cache = step(params, cache, tok, pos + i, sub)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
