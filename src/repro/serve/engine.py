"""Serving engine: batched prefill + fully on-device decode.

``ServeConfig.decode_loop`` picks the loop:

  scan — the production path: the whole decode runs inside ONE jitted
         ``lax.scan`` (sampling included), so there is exactly one compile
         and zero per-token host round-trips.  The KV-cache buffers are
         donated into the loop so the scan's in-place ``dynamic_update_slice``
         writes reuse them instead of copying.
  host — one jitted step per token, dispatched from Python; the debugging
         fallback (inspectable per-token state) and the dispatch-overhead
         baseline the benchmark compares against.

Step/loop functions are compiled once per (model config, serve config
[, horizon]) and cached — repeated ``generate`` calls re-trace nothing.
Greedy decode (``temperature == 0``) never touches the PRNG: no split, no
key threading.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ServeConfig

I32 = jnp.int32

_PREFILL_CACHE: dict = {}
_STEP_CACHE: dict = {}
_LOOP_CACHE: dict = {}
_CACHE_CAP = 32  # compiled entries per cache; oldest evicted (re-jit on miss)


def _cache_put(cache: dict, key, value):
    """Insert with FIFO eviction so a long-lived server with many distinct
    (config, horizon) combinations doesn't retain executables unboundedly."""
    while len(cache) >= _CACHE_CAP:
        cache.pop(next(iter(cache)))
    cache[key] = value
    return value


def build_prefill(model):
    """Jit'd (params, cache, batch) -> (logits, cache, len).

    The eager prefill used to re-trace the whole stack op-by-op on every
    ``generate`` call; jitted + cached it compiles once per model config.
    The incoming (empty) cache is donated — prefill overwrites it anyway.
    """
    ck = model.cfg
    if ck not in _PREFILL_CACHE:
        def prefill(params, cache, batch):
            with jax.named_scope("prefill"):
                return model.prefill(params, cache, batch)
        return _cache_put(_PREFILL_CACHE, ck,
                          jax.jit(prefill, donate_argnums=(1,)))
    return _PREFILL_CACHE[ck]


def _sample(logits, key, temperature, top_k: int = 0, top_p: float = 1.0):
    """logits (B, V) -> token ids (B,).  Greedy when temperature == 0.

    ``top_k`` (0 = off) keeps only the k highest logits; ``top_p`` (1.0 =
    off) keeps the smallest set of tokens whose probability mass reaches p
    (the top token always survives).  Both filter the temperature-scaled
    logits, top-k first then the nucleus — the usual serving-stack order.
    """
    if top_k < 0:
        raise ValueError(f"top_k must be >= 0 (0 = off), got {top_k}")
    if not 0.0 < top_p <= 1.0:
        # top_p <= 0 would empty the nucleus and silently emit token 0
        raise ValueError(f"top_p must be in (0, 1], got {top_p}")
    if temperature <= 0:
        return jnp.argmax(logits, -1)
    logits = logits / temperature
    V = logits.shape[-1]
    use_k = bool(top_k) and 0 < top_k < V
    if use_k or top_p < 1.0:
        # one descending sort serves both filters — this runs inside the
        # jitted per-token decode loops
        srt = jnp.sort(logits, axis=-1)[..., ::-1]
        if use_k:
            logits = jnp.where(logits < srt[..., top_k - 1][..., None],
                               -jnp.inf, logits)
            # the nucleus is computed over the top-k-filtered distribution
            srt = jnp.where(jnp.arange(V) < top_k, srt, -jnp.inf)
        if top_p < 1.0:
            prob = jax.nn.softmax(srt, axis=-1)
            # keep while the mass BEFORE a token is < p: the minimal
            # nucleus, and the top token is always kept (its exclusive
            # prefix mass is 0)
            keep = (jnp.cumsum(prob, axis=-1) - prob) < top_p
            thresh = jnp.min(jnp.where(keep, srt, jnp.inf), axis=-1,
                             keepdims=True)
            logits = jnp.where(logits < thresh, -jnp.inf, logits)
    return jax.random.categorical(key, logits, -1)


def build_serve_step(model, scfg: ServeConfig):
    """Jit'd (params, cache, tokens1, pos, key) -> (next_token, cache).

    Cached per (model config, serve config): repeated ``generate`` calls
    reuse the same compiled step instead of re-jitting every time.  The
    cache is donated — the host loop rebinds it every token, so without
    donation each step copied the entire KV cache just to append one row.
    """
    ck = (model.cfg, scfg)
    if ck not in _STEP_CACHE:
        @functools.partial(jax.jit, donate_argnums=(1,))
        def step(params, cache, tokens1, pos, key):
            with jax.named_scope("serve_step"):
                logits, cache = model.decode_step(params, cache, tokens1,
                                                  pos)
                nxt = _sample(logits[:, -1, :], key, scfg.temperature,
                              scfg.top_k, scfg.top_p)
            return nxt.astype(I32)[:, None], cache
        _cache_put(_STEP_CACHE, ck, step)
    return _STEP_CACHE[ck]


def build_decode_loop(model, scfg: ServeConfig, steps: int):
    """Jit'd (params, cache, tok0, pos0, key) -> ((B, steps) tokens, cache).

    The whole decode is one ``lax.scan`` on device: each iteration appends
    to the KV cache at ``pos0 + i``, samples (or argmaxes) the next token,
    and feeds it back — no host in the loop.  The cache argument is donated
    so the scan updates its buffers in place.
    """
    ck = (model.cfg, scfg, steps)
    if ck not in _LOOP_CACHE:
        @functools.partial(jax.jit, donate_argnums=(1,))
        def loop(params, cache, tok0, pos0, key):
            def body(carry, i):
                with jax.named_scope("decode_step"):
                    cache_c, tok, key_c = carry
                    if scfg.temperature > 0:
                        key_c, sub = jax.random.split(key_c)
                    else:
                        sub = key_c
                    logits, cache_c = model.decode_step(params, cache_c,
                                                        tok, pos0 + i)
                    nxt = _sample(logits[:, -1, :], sub, scfg.temperature,
                                  scfg.top_k, scfg.top_p)
                    tok = nxt.astype(I32)[:, None]
                return (cache_c, tok, key_c), tok[:, 0]
            (cache, _, _), toks = jax.lax.scan(body, (cache, tok0, key),
                                               jnp.arange(steps, dtype=I32))
            # the final cache is returned so the donated input buffers have
            # an output to alias with (true in-place scan on TPU)
            return toks.T, cache
        _cache_put(_LOOP_CACHE, ck, loop)
    return _LOOP_CACHE[ck]


_CHUNK_CACHE: dict = {}


def build_prefill_chunk(model, scfg: ServeConfig, width: int):
    """Jit'd chunked attend-at-offset prefill over a (slot-pool) cache.

    (params, cache, toks (B, width), start (B,), n_valid (B,), gate (B,)) ->
    (last_logits (B, V), cache).  One ``model.prefill_chunk`` call writes
    row ``b``'s first ``n_valid[b]`` tokens at positions ``start[b] ..`` and
    attends each against the full cached history under its own causal
    frontier; the returned logits are each gated row's lane ``n_valid - 1``
    — the next-token logits after its chunk.  Rows with ``gate`` False
    compute but never write, so the rest of the pool is untouched — a long
    prompt admits as a *sequence* of these calls (start advancing by the
    chunk width) interleaved with decode bursts, and prefix-cache hits skip
    straight to their un-cached suffix.  This one executable replaced the
    dense group prefill, the paged cold prefill + page copy, the
    teacher-forced suffix loop, and the spec drafter's sync path.
    """
    ck = (model.cfg, scfg, width)
    if ck in _CHUNK_CACHE:
        return _CHUNK_CACHE[ck]

    @functools.partial(jax.jit, donate_argnums=(1,))
    def chunk(params, cache, toks, start, n_valid, gate):
        with jax.named_scope("prefill_chunk"):
            logits, cache = model.prefill_chunk(params, cache, toks, start,
                                                lengths=n_valid,
                                                write_mask=gate)
            pick = jnp.maximum(n_valid - 1, 0).astype(I32)[:, None, None]
            last = jnp.take_along_axis(logits, pick, axis=1)[:, 0]
        return last.astype(jnp.float32), cache

    return _cache_put(_CHUNK_CACHE, ck, chunk)


def generate(model, params, batch: dict, scfg: ServeConfig, max_new: int,
             key=None, tracer=None, profile=None):
    """Prefill the prompt then decode ``max_new`` tokens. Returns (B, max_new).

    ``tracer``: optional ``repro.obs.trace.Tracer`` — the host decode loop
    and the prefill/scan dispatches run under spans when provided.
    ``profile``: optional ``repro.obs.profile.CostBook`` — executable costs
    are recorded before each dispatch and joined with measured walls (the
    extra ``block_until_ready`` syncs only happen with a book attached)."""
    if tracer is None:
        from repro.obs.trace import NULL_TRACER
        tracer = NULL_TRACER
    if profile is not None and profile.enabled:
        from repro.roofline.analysis import scan_trip_factor
    else:
        profile = scan_trip_factor = None
    key = key if key is not None else jax.random.PRNGKey(0)
    from repro.models import resolve_attn_mode
    model = resolve_attn_mode(model, scfg.attn_mode)
    B = batch["tokens"].shape[0]
    cache = model.init_cache(params, B, scfg.max_len, scfg.cache_dtype)
    if model.init_paged_cache is not None:
        # attention families prefill through the SAME chunked
        # attend-at-offset primitive the slot-pool scheduler admits with —
        # write-then-attend against the cache, so solo outputs match pooled
        # serving by construction for every cache dtype (fp2fx8 included:
        # the prompt reads quantized KV exactly like decode does)
        toks = jnp.asarray(batch["tokens"], I32)
        S = toks.shape[1]
        lens = batch.get("lengths")
        nv = (jnp.asarray(lens, I32) if lens is not None
              else jnp.full((B,), S, I32))
        pc = build_prefill_chunk(model, scfg, S)
        pc_args = (params, cache, toks, jnp.zeros((B,), I32), nv,
                   jnp.ones((B,), bool))
        if profile is not None:  # record before the call: cache is donated
            profile.record(f"prefill_chunk[w={S}]", pc, *pc_args,
                           trip_factor=scan_trip_factor(
                               model.cfg, "prefill", S, 1, 1))
            t_pc = time.perf_counter()
            last, cache = pc(*pc_args)
            jax.block_until_ready(last)
            profile.observe(f"prefill_chunk[w={S}]",
                            time.perf_counter() - t_pc)
        else:
            last, cache = pc(*pc_args)
        pos = S
    else:
        logits, cache, pos = build_prefill(model)(params, cache, batch)
        last = logits[:, -1, :] if logits.ndim == 3 else logits
    # the FIRST generated token comes from the prefill logits — it must be
    # sampled too when temperature > 0 (it used to be unconditionally argmax,
    # which made every decode start greedy)
    if scfg.temperature > 0:
        key, sub = jax.random.split(key)
    else:
        sub = key
    tok = _sample(last, sub, scfg.temperature, scfg.top_k,
                  scfg.top_p).astype(I32)[:, None]

    if scfg.decode_loop == "host":
        out = [tok]
        step = build_serve_step(model, scfg)
        if profile is not None and max_new > 1:
            profile.record("serve_step", step, params, cache, tok, pos, key,
                           trip_factor=scan_trip_factor(
                               model.cfg, "decode", 1, 1, 1))
        with tracer.span("decode_host_loop", steps=max_new - 1):
            t_loop = time.perf_counter()
            for i in range(max_new - 1):
                if scfg.temperature > 0:
                    key, sub = jax.random.split(key)
                else:
                    sub = key
                tok, cache = step(params, cache, tok, pos + i, sub)
                out.append(tok)
            if profile is not None and max_new > 1:
                jax.block_until_ready(tok)
                # the loop wall over the step count: per-step mean — the
                # per-step syncs a per-dispatch join would need distort
                # exactly the pipelining the host loop is benched for
                profile.observe("serve_step", (time.perf_counter() - t_loop)
                                / (max_new - 1))
        return jnp.concatenate(out, axis=1)

    if max_new <= 1:
        return tok
    with tracer.span("decode_scan", steps=max_new - 1):
        loop = build_decode_loop(model, scfg, max_new - 1)
        name = f"decode_loop[steps={max_new - 1}]"
        if profile is not None:
            profile.record(name, loop, params, cache, tok, pos, key,
                           trip_factor=(max_new - 1) * scan_trip_factor(
                               model.cfg, "decode", 1, 1, 1))
            t_loop = time.perf_counter()
            toks, _ = loop(params, cache, tok, pos, key)
            jax.block_until_ready(toks)
            profile.observe(name, time.perf_counter() - t_loop)
        else:
            toks, _ = loop(params, cache, tok, pos, key)
    return jnp.concatenate([tok, toks], axis=1)
