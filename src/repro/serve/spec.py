"""Speculative decoding: self-drafting, batched Hyft verify, KV rollback.

Decode is latency-bound on softmax-heavy one-token steps — the exact regime
the paper builds the reconfigurable datapath for.  Speculative decoding
converts those steps into prefill-shaped multi-token verification: draft K
cheap tokens per slot, score ``[last_token, draft_1..K]`` in ONE model call
through the masked prefill-style Hyft path, and keep the longest accepted
prefix.  The softmax work batches along the sequence axis (the regime the
Samsung softmax-approximation line also identifies as the cheap one), so
every accepted draft amortizes the per-call overhead that dominates decode.
Verification is exact: a drafter only moves the acceptance rate, never the
output.

Three pieces (DESIGN.md §11):

  drafters  — ``NgramDrafter``: deterministic prompt-lookup self-drafting
              (no second model), so greedy spec decode is token-for-token
              identical to vanilla greedy decode by construction.
              ``ModelDrafter``: a small zoo model sharing the slot pool
              with its own dense KV cache, synced lazily by teacher-forcing
              the tokens the target accepted since the last draft.
  verify    — ``build_spec_step``: one jitted call running
              ``model.prefill_chunk`` (the chunked attend-at-offset
              primitive, DESIGN.md §12 — the split-K ``flash_hyft_verify``
              kernel under ``attn_mode="kernel"``, dense or paged,
              fp2fx8 dequant fused into the loads), then the
              longest-accepted-prefix selection with EOS/budget applied to
              ACCEPTED tokens only — all on device.
  rollback  — rejected lanes need no KV undo: they sit past the slot's
              post-acceptance length, invisible to the ``kv_index <=
              position`` mask until overwritten (dense rewind-by-length).
              Paged slots additionally un-append tail pages in the
              scheduler (``SlotPoolEngine._rollback_spec_pages``),
              refcount-correct so radix-trie-shared pages are untouched.

The scheduler integration (``ServeConfig.scheduler = "spec"``) lives in
``repro.serve.scheduler``; this module is the drafting + verify arithmetic.

Exactness caveat — MoE: capacity-bounded expert routing dispatches tokens
batch-globally, so scoring ``K + 1`` lanes per slot routes (and drops)
differently than one-token steps would.  This is the SAME parity exception
the slot-pool scheduler already documents for any batched MoE serving
(DESIGN.md §9) with one more coupling axis: under spec, greedy MoE outputs
may differ from the sequential greedy trajectory, not just from a solo
run.  Attention-family dense/vlm models carry the full token-for-token
guarantee (`tests/test_spec_decode.py`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ServeConfig
from repro.obs import numerics as obs_numerics
from repro.serve import engine
from repro.serve.scheduler import PAD, _bucket  # one emitted-lane filler

I32 = jnp.int32


# --------------------------------------------------------------------------
# drafters
# --------------------------------------------------------------------------


class NgramDrafter:
    """Prompt-lookup / n-gram self-drafting (no second model).

    The draft for a context is the continuation of the most recent earlier
    occurrence of the context's longest trailing n-gram (n from
    ``ngram_max`` down to 1, recency winning ties — repetitive contexts
    keep drafting from their latest loop iteration).  Deterministic and
    model-free: every draft is a literal continuation of the context, and a
    wrong draft costs only its rejected verify lanes.
    """

    model_calls = 0  # drafting never invokes a model

    def __init__(self, ngram_max: int = 3, window: int = 1024):
        if ngram_max < 1:
            raise ValueError("ngram_max must be >= 1")
        self.ngram_max = ngram_max
        # the lookup scans only the most recent ``window`` tokens: recency
        # wins anyway, and an unbounded scan would make host drafting
        # O(L^2) over a long request's lifetime
        self.window = window

    def draft(self, context, k: int) -> np.ndarray:
        """Up to ``k`` draft tokens continuing ``context`` ((L,) ints);
        empty when no trailing n-gram recurs earlier in the context.

        Among occurrences of the trailing n-gram, the most recent one with
        a FULL ``k``-token continuation wins; if every recent occurrence is
        cut off by the context end (the tail of a tight repeat loop), the
        most recent one is used anyway — a short draft beats none.
        """
        ctx = np.asarray(context, np.int64)[-self.window:]
        L = len(ctx)
        if k <= 0 or L < 2:
            return np.empty(0, np.int32)
        for n in range(min(self.ngram_max, L - 1), 0, -1):
            pat = ctx[L - n:]
            # one vectorized sliding-window match per n — this runs on the
            # host every spec burst for every slot, so no Python-level scan
            win = np.lib.stride_tricks.sliding_window_view(ctx[:-1], n)
            hits = np.flatnonzero((win == pat).all(axis=1))
            if hits.size == 0:
                continue
            full = hits[hits + n + k <= L]
            best = int(full[-1]) if full.size else int(hits[-1])
            return ctx[best + n:best + n + k].astype(np.int32)
        return np.empty(0, np.int32)

    def reset_slot(self, s: int) -> None:  # stateless: nothing to reset
        pass

    def draft_batch(self, contexts, want, k: int):
        """Per-slot drafts.  ``contexts``: list of per-slot token arrays
        (None = slot idle); ``want`` (n_slots,): per-slot draft budget.
        Returns (draft (n_slots, k) int32, n_draft (n_slots,) int32)."""
        n = len(contexts)
        draft = np.zeros((n, k), np.int32)
        n_draft = np.zeros(n, np.int32)
        for s, ctx in enumerate(contexts):
            if ctx is None or want[s] <= 0:
                continue
            d = self.draft(ctx, int(min(want[s], k)))
            n_draft[s] = len(d)
            draft[s, :len(d)] = d
        return draft, n_draft


_DRAFT_LOOP_CACHE: dict = {}


def _draft_loop(model, steps: int, max_len: int):
    """Jit'd greedy draft continuation over the DRAFT model's slot cache:
    (params, cache, tok0 (B,1), pos0 (B,), gate (B,)) ->
    ((B, steps) tokens, cache).  Writes gate off past ``max_len`` so a
    nearly-full slot can keep drafting for its neighbours' chunk width."""
    ck = (model.cfg, steps, max_len)
    if ck in _DRAFT_LOOP_CACHE:
        return _DRAFT_LOOP_CACHE[ck]

    @functools.partial(jax.jit, donate_argnums=(1,))
    def loop(params, cache, tok0, pos0, gate):
        def body(carry, i):
            cache_c, tok = carry
            wm = gate & (pos0 + i < max_len)
            logits, cache_c = model.decode_step(params, cache_c, tok,
                                                pos0 + i, write_mask=wm)
            nxt = jnp.argmax(logits[:, -1, :], -1).astype(I32)[:, None]
            return (cache_c, nxt), nxt[:, 0]

        (cache, _), toks = jax.lax.scan(body, (cache, tok0),
                                        jnp.arange(steps, dtype=I32))
        return toks.T, cache

    return engine._cache_put(_DRAFT_LOOP_CACHE, ck, loop)


class ModelDrafter:
    """Small-model drafter sharing the slot pool.

    The draft model keeps its own dense KV cache over the SAME slot ids and
    syncs lazily: before drafting, the tokens the target accepted since the
    drafter's last sync are pushed into its cache through
    ``engine.build_prefill_chunk`` (the same chunked attend-at-offset
    executable admission uses), then ``k`` greedy draft tokens are decoded.
    Draft
    writes past the context roll back by length exactly like the target's
    own rewind: the next sync overwrites them.

    The draft model must share the target's vocab; its quality only moves
    the acceptance rate — verification is exact, so the output never
    changes.
    """

    def __init__(self, model, params, scfg: ServeConfig):
        from repro.models import resolve_attn_mode
        self.model = resolve_attn_mode(model, scfg.attn_mode)
        self.params = params
        self.scfg = scfg
        n = scfg.n_slots
        # drafts are advisory: the draft cache stays dense float32 whatever
        # the target's layout — a drafter never pages and never quantizes
        self.cache = self.model.init_cache(params, n, scfg.max_len,
                                           "float32")
        self.d_len = np.zeros(n, np.int32)  # tokens synced per slot
        # jitted draft-model invocations (teacher syncs + draft loops) —
        # the scheduler folds the per-burst delta into stats["model_calls"]
        # so tokens-per-model-call stays honest for the model drafter
        self.model_calls = 0

    def reset_slot(self, s: int) -> None:
        self.d_len[s] = 0

    def draft_batch(self, contexts, want, k: int):
        n = self.scfg.n_slots
        draft = np.zeros((n, k), np.int32)
        n_draft = np.zeros(n, np.int32)
        gate = np.zeros(n, bool)
        delta = np.ones(n, np.int32)
        for s, ctx in enumerate(contexts):
            if ctx is None or want[s] <= 0:
                continue
            gate[s] = True
            delta[s] = len(ctx) - self.d_len[s]
        if not gate.any() or k <= 0:
            return draft, n_draft
        assert delta.min() >= 1, "drafter context shrank or did not grow"

        # ---- sync: teacher-force the un-synced context suffix ------------
        m = _bucket(int(delta.max()), lo=1)
        toks = np.zeros((n, m), np.int32)
        start = np.array(self.d_len, np.int32)
        nv = np.ones(n, np.int32)
        for s, ctx in enumerate(contexts):
            if not gate[s]:
                continue
            suf = np.asarray(ctx, np.int32)[self.d_len[s]:]
            toks[s, :len(suf)] = suf
            nv[s] = len(suf)
        sync = engine.build_prefill_chunk(self.model, self.scfg, m)
        last, self.cache = sync(self.params, self.cache,
                                jnp.asarray(toks), jnp.asarray(start),
                                jnp.asarray(nv), jnp.asarray(gate))
        self.model_calls += 1
        d1 = np.asarray(jnp.argmax(last, -1), np.int32)

        # ---- draft: k - 1 more greedy tokens, then rewind by length ------
        pos0 = np.array([len(ctx) if gate[s] else 0
                         for s, ctx in enumerate(contexts)], np.int32)
        rest = None
        if k > 1:
            loop = _draft_loop(self.model, k - 1, self.scfg.max_len)
            rest, self.cache = loop(self.params, self.cache,
                                    jnp.asarray(d1)[:, None],
                                    jnp.asarray(pos0), jnp.asarray(gate))
            self.model_calls += 1
            rest = np.asarray(rest)
        for s in range(n):
            if not gate[s]:
                continue
            row = np.concatenate([[d1[s]], rest[s]]) if k > 1 \
                else np.array([d1[s]], np.int32)
            w = int(min(want[s], k))
            n_draft[s] = w
            draft[s, :w] = row[:w]
            self.d_len[s] = len(contexts[s])  # rollback: drafts not kept
        return draft, n_draft


# --------------------------------------------------------------------------
# jitted verify + longest-accepted-prefix step
# --------------------------------------------------------------------------


_SPEC_CACHE: dict = {}


def build_spec_step(model, scfg: ServeConfig, k: int):
    """Jit'd (params, cache, last_tok (B,1), draft (B,k), n_draft (B,),
    lengths (B,), active (B,), budget (B,)) -> (emitted (B, k+1)
    PAD-padded, cache, last_tok, lengths, active, budget, n_acc (B,),
    ok (B,), tstats).  ``tstats`` is the per-step hybrid-format telemetry
    dict (DESIGN.md §15) — empty unless ``scfg.telemetry`` is on, else the
    valid verify lanes' exponent-range stats plus the cache's fp2fx8
    scale/saturation stats.  ``ok`` is the numeric-health bit the
    robustness layer keys
    on (DESIGN.md §13): False where any VALID verify lane of an active slot
    produced non-finite logits — the scheduler discards that slot's step
    and quarantines it (idle slots and padding lanes report True).

    One ``model.prefill_chunk`` call scores ``[last_tok, draft_1..k]``: lane
    ``j``'s argmax is the token sequential greedy decode would emit after
    ``j`` accepted drafts, so the longest prefix with ``draft[j] ==
    argmax[j-1]`` (a cumprod of matches — monotone, no scan) IS the vanilla
    continuation, and one bonus token always comes free from the lane after
    it.  EOS and budget act on ACCEPTED tokens only: emission truncates at
    the first EOS / remaining budget, each slot's length advances by its
    emitted count (the dense KV rewind — rejected lanes sit past the new
    length, masked until overwritten), and ``active`` drops on device
    exactly as in the plain burst.  Greedy-only by design: sampled
    acceptance needs the top-k/top-p machinery as a distribution, not a
    filter (the groundwork is in ``engine._sample``).
    """
    eos = scfg.eos_id
    S = k + 1
    ck = (model.cfg, scfg, k)
    if ck in _SPEC_CACHE:
        return _SPEC_CACHE[ck]

    @functools.partial(jax.jit, donate_argnums=(1,))
    def step(params, cache, last_tok, draft, n_draft, lengths, active,
             budget):
        toks = jnp.concatenate([last_tok, draft], axis=1)          # (B, S)
        n_valid = jnp.where(active, n_draft + 1, 1)
        with jax.named_scope("spec_verify"):
            logits, cache = model.prefill_chunk(params, cache, toks,
                                                lengths, lengths=n_valid,
                                                write_mask=active)
        greedy = jnp.argmax(logits, -1).astype(I32)                # (B, S)
        lane = jnp.arange(S, dtype=I32)[None]
        lane_ok = jnp.isfinite(logits).all(-1)                     # (B, S)
        ok = (lane_ok | (lane >= n_valid[:, None])).all(1) | ~active
        if scfg.telemetry:
            lane_act = active[:, None] & (lane < n_valid[:, None])
            zs = obs_numerics.logit_stats(
                logits.reshape(-1, logits.shape[-1]), lane_act.reshape(-1))
            tstats = dict(z_max=zs[0], z_min=zs[1], zsub_min=zs[2],
                          **obs_numerics.format_stats(cache))
        else:
            tstats = {}
        dmask = jnp.arange(k, dtype=I32)[None] < n_draft[:, None]
        match = (draft == greedy[:, :-1]) & dmask
        n_acc = jnp.sum(jnp.cumprod(match.astype(I32), axis=1), axis=1)
        n_emit = jnp.minimum(n_acc + 1, budget)
        if eos is not None:
            is_eos = (greedy == eos) & (lane < n_emit[:, None])
            first = jnp.min(jnp.where(is_eos, lane, S), axis=1)
            n_emit = jnp.minimum(n_emit, first + 1)
            hit_eos = first < S
        else:
            hit_eos = jnp.zeros(active.shape, bool)
        n_emit = jnp.where(active, n_emit, 0)
        emitted = jnp.where(lane < n_emit[:, None], greedy, PAD)
        pick = jnp.maximum(n_emit - 1, 0)[:, None]
        new_last = jnp.take_along_axis(greedy, pick, axis=1)[:, 0]
        last_tok = jnp.where(active, new_last, last_tok[:, 0])[:, None]
        lengths = lengths + n_emit
        budget = budget - n_emit
        active = active & (budget > 0) & ~hit_eos
        return emitted, cache, last_tok, lengths, active, budget, \
            n_acc, ok, tstats

    return engine._cache_put(_SPEC_CACHE, ck, step)


def make_drafter(scfg: ServeConfig, target_cfg, draft=None):
    """Resolve ``scfg.spec_mode`` to a drafter instance.

    ``draft``: optional (model, params) pair for ``spec_mode="model"`` —
    required unless ``scfg.draft_model`` names a zoo arch, in which case a
    RANDOM-init smoke drafter is built (vocab-aligned to the target; a
    demo drafter whose acceptance floor is chance, not a good one).
    """
    if scfg.spec_mode == "ngram":
        return NgramDrafter(scfg.ngram_max)
    if scfg.spec_mode == "model":
        if draft is None:
            if not scfg.draft_model:
                raise ValueError(
                    "spec_mode='model' needs draft=(model, params) or "
                    "ServeConfig.draft_model naming a zoo arch")
            from repro.configs import get_config, smoke_config
            from repro.models import build_model
            from repro.models.layers import unbox
            dcfg = smoke_config(get_config(scfg.draft_model)).with_(
                vocab=target_cfg.vocab,
                softmax_impl=target_cfg.softmax_impl)
            dmodel = build_model(dcfg)
            draft = (dmodel, unbox(dmodel.init(jax.random.PRNGKey(1))))
        dmodel, dparams = draft
        if dmodel.cfg.vocab != target_cfg.vocab:
            raise ValueError(
                f"draft model vocab {dmodel.cfg.vocab} != target vocab "
                f"{target_cfg.vocab}")
        return ModelDrafter(dmodel, dparams, scfg)
    raise ValueError(f"unknown spec_mode {scfg.spec_mode!r}")
