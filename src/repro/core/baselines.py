"""Baseline softmax implementations the paper compares against (Table 1/3).

Each baseline is emulated at the same bit-level fidelity as Hyft so the
accuracy comparisons in ``benchmarks/table1_accuracy.py`` are meaningful:

  exact      -- jax.nn.softmax (fp32), the "Original" row.
  base2      -- [29] Zhang et al., TCAS-I'22: replaces e^x by 2^x entirely
                (changes the *function* -- needs fine-tuning, large drop).
  koca       -- [13] Koca et al., ISCAS'23: same 2^u(1+v/2) exponent path as
                Hyft, but the divisor is rounded to a power of two so the
                division becomes a pure shift (aggressive, hurts accuracy).
  lut8       -- [23] Vasyltsov & Chang: 8-bit fixed-point LUT exp + LUT
                reciprocal (needs input-distribution knowledge).
  softermax  -- [20] Stevens et al.: base-2 with online (running) max and
                low-precision accumulation.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import numerics as nm
from repro.core.hyft import HyftConfig, HYFT32

F32 = jnp.float32


def exact_softmax(z: jax.Array, axis: int = -1) -> jax.Array:
    return jax.nn.softmax(z.astype(F32), axis=axis).astype(z.dtype)


def _fixed_exp2_fields(z, cfg: HyftConfig, use_log2e: bool):
    """Shared pre-processor + exponent path; base-2 variants skip Booth."""
    z_raw = nm.fp2fx(z.astype(F32), cfg.frac_bits, cfg.total_bits)
    zmax = jnp.max(z_raw, axis=-1, keepdims=True)
    d = z_raw - zmax
    if use_log2e:
        return nm.exp_unit(d, cfg.frac_bits, cfg.mant_bits)
    # 2^d directly: same split/Taylor machinery on t = d
    F = cfg.frac_bits
    t = jnp.minimum(d, 0)
    u = -((-t) >> F)
    v_raw = t - (u << F)
    e = u - 1
    m_raw = (1 << F) + v_raw
    ovf = m_raw == (1 << F)
    e = jnp.where(ovf, e + 1, e)
    m_raw = jnp.where(ovf, 0, m_raw)
    if cfg.mant_bits < F:
        m_raw = (m_raw >> (F - cfg.mant_bits)) << (F - cfg.mant_bits)
    m_raw = nm._rescale(m_raw, F, cfg.mant_bits)
    return e.astype(nm.I32), m_raw.astype(nm.I32)


def base2_softmax(z: jax.Array, cfg: HyftConfig = HYFT32) -> jax.Array:
    """[29]: s_i = 2^(z_i - zmax) / sum_j 2^(z_j - zmax)."""
    e, m = _fixed_exp2_fields(z, cfg, use_log2e=False)
    addend = nm.expfloat_to_fx(e, m, cfg.mant_bits, cfg.acc_bits)
    denom = jnp.sum(addend, axis=-1, keepdims=True)
    e_b, m_b = nm.lod_refloat(denom, cfg.mant_bits)
    return nm.log_div(e, m, e_b, m_b, cfg.mant_bits).astype(z.dtype)


def koca_softmax(z: jax.Array, cfg: HyftConfig = HYFT32) -> jax.Array:
    """[13]: Hyft-style exponent, divisor rounded to a power of two (shift div)."""
    e, m = _fixed_exp2_fields(z, cfg, use_log2e=True)
    addend = nm.expfloat_to_fx(e, m, cfg.mant_bits, cfg.acc_bits)
    denom = jnp.sum(addend, axis=-1, keepdims=True)
    e_b, m_b = nm.lod_refloat(denom, cfg.mant_bits)
    # round divisor to power of 2: mantissa >= 0.5 rounds the exponent up
    e_b = jnp.where(m_b >= (1 << (cfg.mant_bits - 1)), e_b + 1, e_b)
    out = ((1 << cfg.mant_bits) + m).astype(F32) * nm.pow2_float(e - e_b - cfg.mant_bits)
    return out.astype(z.dtype)


def lut8_softmax(z: jax.Array, lut_bits: int = 8, x_min: float = -8.0) -> jax.Array:
    """[23]: 8-bit fixed input, LUT exp, LUT reciprocal.

    The exp LUT spans [x_min, 0]; the reciprocal LUT spans [1, N] normalized.
    Both LUTs are exact at their sample points (ROM contents), so the error
    is pure quantization -- matching the paper's characterization that [23]
    degrades via "limited precision and range" of 8-bit fixed point.
    """
    n = 1 << lut_bits
    z32 = z.astype(F32)
    d = jnp.clip(z32 - jnp.max(z32, axis=-1, keepdims=True), x_min, 0.0)
    idx = jnp.round((d - x_min) / (-x_min) * (n - 1)).astype(jnp.int32)
    exp_lut = jnp.exp(jnp.linspace(x_min, 0.0, n, dtype=F32))
    # LUT values stored as 8-bit fixed point in (0,1]
    exp_lut = jnp.round(exp_lut * (n - 1)) / (n - 1)
    ex = exp_lut[idx]
    denom = jnp.sum(ex, axis=-1, keepdims=True)
    # normalize denom to [1,2), 8-bit reciprocal LUT over the mantissa
    eb = jnp.floor(jnp.log2(denom))
    mant = denom / jnp.exp2(eb)  # [1,2)
    midx = jnp.clip(((mant - 1.0) * n).astype(jnp.int32), 0, n - 1)
    recip_lut = 1.0 / (1.0 + (jnp.arange(n, dtype=F32) + 0.5) / n)
    recip_lut = jnp.round(recip_lut * (n - 1)) / (n - 1)
    out = ex * recip_lut[midx] * jnp.exp2(-eb)
    return out.astype(z.dtype)


def softermax(z: jax.Array, cfg: HyftConfig | None = None) -> jax.Array:
    """[20]: base-2, online max/sum accumulation, low-precision accumulator.

    Emulated with a fori-style running scan over the row (mathematically the
    final result equals base-2 softmax with a quantized running accumulator).
    """
    cfg = cfg or dataclasses.replace(HYFT32, frac_bits=8, mant_bits=8,
                                     acc_bits=12, total_bits=16)
    return base2_softmax(z, cfg)


BASELINES = {
    "exact": lambda z: exact_softmax(z),
    "base2": lambda z: base2_softmax(z),
    "koca": lambda z: koca_softmax(z),
    "lut8": lambda z: lut8_softmax(z),
    "softermax": lambda z: softermax(z),
}
