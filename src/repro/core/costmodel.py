"""Fabric-free hardware cost model — reproduces the *structure* of paper Table 3.

LUT/FF/F_max are FPGA-fabric quantities with no TPU meaning, but they are
driven by countable primitive operations.  We count those primitives per
softmax variant and weight them with standard relative-area/delay factors
(barrel shifter ~ W log W, W-bit multiplier ~ W^2, fixed add ~ W, FP add ~
shifter+add+LOD, divider ~ W cycles of sub/shift).  The *ordering* and the
rough ratios of Table 3 (Hyft ~15x fewer resources, ~20x lower latency than
the Xilinx FP32 engine) are the reproducible claims.

Latency model: three stages (max | exp+sum | div) pipelined across vectors
(paper §3.6, Fig. 6); per-vector latency = sum of stage critical paths,
steady-state throughput = 1 / max(stage delay).
"""
from __future__ import annotations

import dataclasses
import math


# relative area (a) and delay (d) of primitive blocks at width W, normalized
# to a W-bit fixed adder = (area W, delay log2 W). Standard synthesis folklore
# constants; absolute values are irrelevant, ratios matter.
def _adder(W):      return dict(a=W,                 d=math.log2(W))
def _shifter(W):    return dict(a=W * math.log2(W),  d=math.log2(W))
def _cmp(W):        return dict(a=W,                 d=math.log2(W))
def _mul(W, W2=None):
    W2 = W2 or W
    return dict(a=W * W2,            d=math.log2(W) + math.log2(max(W2, 2)))
def _lod(W):        return dict(a=W,                 d=math.log2(W))
def _divider(W):    return dict(a=3 * W * W,         d=W * math.log2(W))  # restoring
def _lut(bits, out):return dict(a=(2 ** bits) * out / 64.0, d=2.0)
def _fp_add(W):
    # align shifter + add + renorm LOD + shifter
    s, a, l = _shifter(W), _adder(W), _lod(W)
    return dict(a=2 * s["a"] + a["a"] + l["a"], d=2 * s["d"] + a["d"] + l["d"])
def _fp_mul(W):
    m, a = _mul(W // 2 + 1), _adder(W // 4)  # mantissa mul + exp add
    return dict(a=m["a"] + a["a"], d=m["d"] + a["d"])


@dataclasses.dataclass
class Cost:
    area: float = 0.0
    stage_delays: tuple = (0.0, 0.0, 0.0)

    @property
    def latency(self):  # one-vector latency (ns-like units)
        return sum(self.stage_delays)

    @property
    def throughput_period(self):  # pipelined: limited by slowest stage
        return max(self.stage_delays)


def _acc(*items):
    return sum(i["a"] for i in items)


def _seq(*items):
    return sum(i["d"] for i in items)


def hyft_cost(N: int = 8, W: int = 16, step: int = 1) -> Cost:
    """Hyft: fixed-point max/sub/booth + field-assembled exp + fixed adder tree
    + field-subtract division.  No FP adds, no divider, no exp LUT."""
    F = W - 6
    # stage 1: strided max search (fixed cmp tree over N/step) + FP2FX banks
    n1 = max(N // step, 1)
    st1_a = (n1 - 1) * _cmp(W)["a"] + (N + 1) * _shifter(W)["a"] * 0.5  # FP2FX ~ half shifter
    st1_d = math.ceil(math.log2(max(n1, 2))) * _cmp(W)["d"] + _shifter(W)["d"] * 0.5
    # stage 2: per-elem fixed sub + booth (2 shifts hardwired = wiring, 2 adds)
    #          + FX2FP assembly (wiring) + FP2FX (shift by exponent) + adder tree
    per_elem = 3 * _adder(W)["a"] + _shifter(W)["a"]
    st2_a = N * per_elem + (N - 1) * _adder(W + math.ceil(math.log2(N)))["a"] + _lod(W)["a"]
    st2_d = _seq(_adder(W), _adder(W), _shifter(W)) + \
        math.ceil(math.log2(N)) * _adder(W)["d"] + _lod(W)["d"]
    # stage 3: division = exp sub + mantissa sub + 1-bit renorm mux, per element
    st3_a = N * 2 * _adder(F)["a"]
    st3_d = 2 * _adder(F)["d"]
    return Cost(st1_a + st2_a + st3_a, (st1_d, st2_d, st3_d))


def xilinx_fp_cost(N: int = 8, W: int = 32) -> Cost:
    """All-FP32 engine: FP cmp max, FP sub, FP exp (poly, ~5 FP mul+add),
    FP adder tree, FP divider."""
    st1_a = (N - 1) * _fp_add(W)["a"]
    st1_d = math.ceil(math.log2(N)) * _fp_add(W)["d"]
    exp_a = 5 * (_fp_mul(W)["a"] + _fp_add(W)["a"])
    exp_d = 5 * (_fp_mul(W)["d"] + _fp_add(W)["d"])
    st2_a = N * (_fp_add(W)["a"] + exp_a) + (N - 1) * _fp_add(W)["a"]
    st2_d = _fp_add(W)["d"] + exp_d + math.ceil(math.log2(N)) * _fp_add(W)["d"]
    st3_a = N * _divider(24)["a"] / 4  # shared pipelined divider bank
    st3_d = _divider(24)["d"]
    return Cost(st1_a + st2_a + st3_a, (st1_d, st2_d, st3_d))


def fixed_lut_cost(N: int = 8, W: int = 16) -> Cost:
    """[25]-style all-fixed: LUT exp + fixed adds + restoring divider."""
    st1_a = (N - 1) * _cmp(W)["a"]
    st1_d = math.ceil(math.log2(N)) * _cmp(W)["d"]
    st2_a = N * (_adder(W)["a"] + _lut(8, W)["a"]) + (N - 1) * _adder(W)["a"]
    st2_d = _adder(W)["d"] + 2.0 + math.ceil(math.log2(N)) * _adder(W)["d"]
    st3_a = N * _divider(W)["a"] / 4
    st3_d = _divider(W)["d"]
    return Cost(st1_a + st2_a + st3_a, (st1_d, st2_d, st3_d))


def base2_cost(N: int = 8, W: int = 16) -> Cost:
    """[29]: like Hyft stage structure but no Booth (base-2), shift division."""
    c = hyft_cost(N, W)
    st1, st2, st3 = c.stage_delays
    # no booth adds in stage 2; division is a shift (power-of-2 divisor)
    return Cost(c.area * 0.9, (st1, st2 - 2 * _adder(W)["d"], _shifter(W)["d"]))


def table3(N: int = 8) -> list[dict]:
    rows = []
    for name, cost, W in [
        ("xilinx_fp32", xilinx_fp_cost(N, 32), 32),
        ("fixed_lut16 [25]", fixed_lut_cost(N, 16), 16),
        ("base2 [29]", base2_cost(N, 16), 16),
        ("hyft16", hyft_cost(N, 16), 16),
        ("hyft16_step2", hyft_cost(N, 16, step=2), 16),
        ("hyft32", hyft_cost(N, 24), 32),
    ]:
        rows.append(dict(name=name, N=N, W=W, area=cost.area,
                         latency=cost.latency, period=cost.throughput_period,
                         fom=N * W / (cost.area * cost.throughput_period)))
    base = next(r for r in rows if r["name"] == "xilinx_fp32")
    for r in rows:
        r["area_ratio_vs_fp32"] = base["area"] / r["area"]
        r["latency_ratio_vs_fp32"] = base["latency"] / r["latency"]
    return rows
