"""Bit-level numeric-format emulation primitives for Hyft.

Hyft's contribution is *adaptive format conversion*: every intermediate value is
carried in whichever format (fixed point vs. float exponent/mantissa fields)
makes the next arithmetic op cheap.  This module provides the exact arithmetic
of each hardware block, emulated with int32 raws / exact fp32 ops so that the
pure-JAX reference and the Pallas kernels are bit-identical.

Conventions
-----------
* A fixed-point value with ``frac_bits=F`` is an int32 ``raw`` with value
  ``raw / 2**F`` (two's complement; arithmetic right shifts == floor division).
* A custom float is an (exponent ``e``:int32, mantissa ``m_raw``:int32) pair
  with value ``2**e * (1 + m_raw / 2**F)``, ``0 <= m_raw < 2**F`` (normalized).
* All helpers are shape-polymorphic and vectorize over leading axes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

I32 = jnp.int32
F32 = jnp.float32

# --------------------------------------------------------------------------
# fixed-point <-> float conversion (the FP2FX / FX2FP blocks)
# --------------------------------------------------------------------------


def fp2fx(x: jax.Array, frac_bits: int, total_bits: int) -> jax.Array:
    """Float -> fixed point raw (int32), round-to-nearest, saturating.

    Emulates the parameterized FP2FX converter of the input pre-processor
    (paper §3.1, ``Precision`` = ``frac_bits``).  +-inf saturate; NaN -> 0 is
    NOT special-cased (garbage-in behaviour matches hardware).
    """
    lo = F32(-(2 ** (total_bits - 1)))
    hi = F32(2 ** (total_bits - 1) - 1)
    scaled = x.astype(F32) * F32(2.0**frac_bits)
    # rint == round-half-even, the usual RTL rounding choice for converters.
    return jnp.clip(jnp.rint(scaled), lo, hi).astype(I32)


def fx2fp(raw: jax.Array, frac_bits: int) -> jax.Array:
    """Fixed point raw -> fp32 (exact while |raw| < 2**24)."""
    return raw.astype(F32) * F32(2.0**-frac_bits)


def pow2_float(k: jax.Array) -> jax.Array:
    """Assemble the fp32 value ``2.0**k`` by writing the exponent field.

    This is the zero-shifter float assembly Hyft relies on: on TPU it is a
    couple of integer VPU ops.  Out-of-range exponents flush to zero
    (k <= -127) or saturate to 2**127 (k >= 128) -- hardware FTZ behaviour.
    """
    k = k.astype(I32)
    biased = jnp.clip(k + 127, 0, 255)
    val = jax.lax.bitcast_convert_type((biased << 23).astype(I32), F32)
    return jnp.where(biased <= 0, F32(0.0), val)


def float_fields(x: jax.Array, mant_bits: int) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Decompose fp32 ``x`` -> (sign, exponent, mantissa raw @ mant_bits).

    Mantissa is truncated (not rounded) to ``mant_bits`` -- the LOD + shifter
    in hardware drops low bits.  Zero/subnormal inputs map to a canonical
    (sign, -127, 0) triple which downstream blocks flush to zero.
    """
    bits = jax.lax.bitcast_convert_type(x.astype(F32), I32)
    sign = (bits >> 31) & 1
    e = ((bits >> 23) & 0xFF) - 127
    m = (bits >> (23 - mant_bits)) & ((1 << mant_bits) - 1)
    return sign.astype(I32), e.astype(I32), m.astype(I32)


def assemble_float(sign: jax.Array, e: jax.Array, m_raw: jax.Array, mant_bits: int) -> jax.Array:
    """(sign, e, m_raw @ mant_bits) -> fp32 value, with FTZ on underflow."""
    mag = (F32(2.0**mant_bits) + m_raw.astype(F32)) * pow2_float(e - mant_bits)
    return jnp.where(sign == 1, -mag, mag)


# --------------------------------------------------------------------------
# the hybrid exponent unit (paper §3.2)
# --------------------------------------------------------------------------


def booth_log2e(d_raw: jax.Array) -> jax.Array:
    """Booth-encoded shift-add approximation of ``d * log2(e)``.

    ``z'*log2e ~= z' + (z' >> 1) - (z' >> 4)``  (1.4375 vs 1.44269...).
    Arithmetic right shifts (floor) exactly as in two's-complement RTL.
    """
    return d_raw + (d_raw >> 1) - (d_raw >> 4)


def exp_unit(d_raw: jax.Array, frac_bits: int, mant_bits: int) -> tuple[jax.Array, jax.Array]:
    """Hybrid exponent unit: fixed-point ``d = z - zmax`` (<=0) -> float fields.

    Returns (e, m_raw) with value ``2**e * (1 + m_raw/2**mant_bits)``
    approximating ``exp(d)``:

      t = d*log2e (shift-add);  u = ceil(t) <= 0;  v = t - u in (-1, 0]
      exp(d) ~= 2**(u+v) ~= 2**u (1 + v/2) = 2**(u-1) (1 + (1+v))

    so exponent field u-1 and mantissa 1+v -- materialized directly, no
    shifter (paper Eq. 8).  The mantissa is then truncated to ``mant_bits``.
    """
    F = frac_bits
    t = booth_log2e(d_raw)
    t = jnp.minimum(t, 0)  # saturate: strided-max may leave d > 0 (paper §3.1)
    # ceil(t / 2**F) for t <= 0 via neg-floor-neg; v_raw = t - (u << F) in (-2**F, 0]
    u = -((-t) >> F)
    v_raw = t - (u << F)
    e = u - 1
    m_raw = (1 << F) + v_raw  # 1 + v, in (0, 2**F]
    # normalize the m == 1.0 edge (v == 0): 2**(u-1)*2 == 2**u * 1.0
    overflow = m_raw == (1 << F)
    e = jnp.where(overflow, e + 1, e)
    m_raw = jnp.where(overflow, 0, m_raw)
    # truncate mantissa to the configured intermediate precision
    if mant_bits < F:
        m_raw = (m_raw >> (F - mant_bits)) << (F - mant_bits)
    # rescale raw to mant_bits so downstream blocks share one scale
    m_raw = _rescale(m_raw, F, mant_bits)
    return e.astype(I32), m_raw.astype(I32)


def _rescale(raw: jax.Array, src_bits: int, dst_bits: int) -> jax.Array:
    if dst_bits == src_bits:
        return raw
    if dst_bits < src_bits:
        return raw >> (src_bits - dst_bits)
    return raw << (dst_bits - src_bits)


# --------------------------------------------------------------------------
# the hybrid adder tree (paper §3.3)
# --------------------------------------------------------------------------


def expfloat_to_fx(e: jax.Array, m_raw: jax.Array, mant_bits: int, acc_bits: int) -> jax.Array:
    """FP2FX at the adder-tree input: value in (0,1] -> fp32 multiple of 2**-acc_bits.

    The quantized value ``floor(val * 2**acc_bits) * 2**-acc_bits`` is returned
    *as fp32* (exact: it is an integer < 2**(acc_bits+1) scaled).  The adder
    tree then accumulates these in fp32, which is exact as long as the running
    sum stays below 2**24 ulps of 2**-acc_bits; both the reference and the
    kernels use the identical accumulation so they agree bit-for-bit (see
    DESIGN.md §2 for the int-width discussion).
    """
    # raw integer at acc_bits scale: (2**mant + m) << (e + acc - mant), >> if negative
    shift = e + acc_bits - mant_bits
    base = (1 << mant_bits) + m_raw
    pos = base << jnp.maximum(shift, 0)
    neg = base >> jnp.minimum(-shift, 31)
    q = jnp.where(shift >= 0, pos, neg)
    # guard: e <= 0 always here, so q <= 2**acc_bits; flush e < -acc_bits-mant to 0
    q = jnp.where(shift <= -32, 0, q)
    return q.astype(F32) * F32(2.0**-acc_bits)


def lod_refloat(s: jax.Array, mant_bits: int) -> tuple[jax.Array, jax.Array]:
    """Leading-one detector: fp32 sum -> (e, m_raw @ mant_bits), truncating.

    Extracting the fields of the fp32 accumulator *is* the LOD + shift: the
    fp32 value is already normalized, we only drop mantissa bits below
    ``mant_bits``.
    """
    _, e, m = float_fields(s, mant_bits)
    return e, m


# --------------------------------------------------------------------------
# the hybrid DIV / MUL unit (paper §3.4 / §3.5)
# --------------------------------------------------------------------------


def log_div(e_a: jax.Array, m_a: jax.Array, e_b: jax.Array, m_b: jax.Array,
            mant_bits: int) -> jax.Array:
    """Log-subtract division  a/b ~= 2**(e_a-e_b+m_a-m_b)  (paper Eq. 9).

    Taylor ``log2(1+x) ~= x`` turns the divide into field subtraction; the
    combined log ``(e_a-e_b) + (m_a-m_b)`` is re-split into integer exponent
    and fractional mantissa (a conditional 1-bit renorm in hardware -- the
    emitted FP16/FP32 output must carry a non-negative mantissa), then
    ``2**frac ~= 1+frac`` maps back out of log space.
    """
    diff = m_a - m_b  # in (-2**mant, 2**mant)
    neg = diff < 0
    # bool -> i32 keeps the conditional renorm weak-type-free: a Python-int
    # where() here broadcast a weak scalar against the whole (..., D) raw
    # tensor and materialized an extra convert in every finalize.
    e = e_a - e_b - neg.astype(I32)
    m = jnp.where(neg, (1 << mant_bits) + diff, diff)  # in [0, 2**mant)
    return ((1 << mant_bits) + m).astype(F32) * pow2_float(e - mant_bits)


def log_mul(a: jax.Array, b: jax.Array, mant_bits: int, half_range: bool = True) -> jax.Array:
    """Hybrid float multiply  a*b ~= 2**(ea+eb) (1 + ma + mb + ma*mb).

    Used by the backward pass (paper Eq. 10).  ``half_range=True`` truncates
    b's mantissa to ``mant_bits//2`` bits before the partial product -- the
    50%-smaller multiplier of §3.5.
    """
    F = mant_bits
    sa, ea, ma = float_fields(a, F)
    sb, eb, mb = float_fields(b, F)
    if half_range:
        top = F - F // 2
        mb_top = mb >> top          # top F//2 bits, value mb_top / 2**(F//2)
        prod = (ma * mb_top) >> (F // 2)   # back to F-scale
    else:
        prod = (ma * mb) >> F
    num = (1 << F) + ma + mb + prod       # in (2**F, 4*2**F)
    mag = num.astype(F32) * pow2_float(ea + eb - F)
    sign = sa ^ sb
    zero = (a == 0.0) | (b == 0.0)
    out = jnp.where(sign == 1, -mag, mag)
    return jnp.where(zero, F32(0.0), out)


def fx_quantize(x: jax.Array, frac_bits: int) -> jax.Array:
    """Two's-complement truncation to ``frac_bits`` fractional bits, in fp32.

    ``floor(x * 2**F) / 2**F`` -- used by the backward adder tree on signed
    addends.  Exact in fp32 for |x| < 2**(24-F).
    """
    s = F32(2.0**frac_bits)
    return jnp.floor(x.astype(F32) * s) * F32(1.0 / s)
