"""Hyft softmax — pure-JAX, bit-level-faithful emulation (fwd + bwd).

This is the paper's contribution as a composable JAX module.  The Pallas
kernels in ``repro.kernels`` implement the identical arithmetic with int32
bit manipulation; this module is the oracle they are validated against, and
it is also what runs inside every model when ``softmax="hyft*"`` is selected
(on CPU, or when kernels are disabled).

The emulation follows the four hardware blocks exactly (see DESIGN.md §1-2):

  pre-processor  : strided max (STEP) + FP2FX @ ``frac_bits`` (Precision)
  exponent unit  : shift-add z*log2e -> split u,v -> 2**(u-1)(1+(1+v)) fields
  adder tree     : FP2FX @ ``acc_bits`` -> exact accumulate -> LOD refloat
  div/mul unit   : log-subtract divide; log-domain multiply for backward
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core import numerics as nm

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class HyftConfig:
    """Reconfigurable parameters of the accelerator (paper §3.1/§3.3).

    Attributes:
      io_dtype:   input/output format ("float16" = Hyft16, "float32" = Hyft32,
                  "bfloat16" = Hyft16b, our TPU-native extension).
      total_bits: width W of the fixed-point input format (pre-processor).
      frac_bits:  the ``Precision`` parameter -- fractional bits of the
                  fixed-point input format.
      mant_bits:  mantissa bits carried by the intermediate float fields.
      acc_bits:   fractional bits of the hybrid adder tree (values in (0,1]).
      step:       STEP parameter of the strided max search (1 = exact max).
      grad:       "hyft" = backward via the reused div/mul unit (paper §3.5);
                  "exact" = exact softmax VJP (ablation).
      bwd_acc_bits: adder-tree precision for the backward dot product.
    """

    io_dtype: str = "float32"
    total_bits: int = 24
    frac_bits: int = 16
    mant_bits: int = 16
    acc_bits: int = 20
    step: int = 1
    grad: Literal["hyft", "exact"] = "hyft"
    bwd_acc_bits: int = 16

    def __post_init__(self):
        assert self.frac_bits < self.total_bits <= 31
        assert self.mant_bits <= self.frac_bits, "mantissa derives from v's frac bits"
        assert self.acc_bits <= 22, "adder tree addends must stay exact in fp32"
        assert self.step >= 1

    @property
    def dtype(self):
        return jnp.dtype(self.io_dtype)


# Hyft16 / Hyft32 presets from the paper's two evaluated configurations.
HYFT16 = HyftConfig(io_dtype="float16", total_bits=16, frac_bits=10,
                    mant_bits=10, acc_bits=14, bwd_acc_bits=12)
HYFT32 = HyftConfig(io_dtype="float32", total_bits=24, frac_bits=16,
                    mant_bits=16, acc_bits=20, bwd_acc_bits=16)
# TPU-native extension (bf16 I/O keeps the wide exponent; same internal path).
HYFT16B = dataclasses.replace(HYFT16, io_dtype="bfloat16")


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------


def strided_max(z_raw: jax.Array, step: int) -> jax.Array:
    """Approximate max search over every ``step``-th element (paper §3.1)."""
    if step > 1:
        z_raw = z_raw[..., ::step]
    return jnp.max(z_raw, axis=-1, keepdims=True)


def hyft_exp_fields(z: jax.Array, cfg: HyftConfig) -> tuple[jax.Array, jax.Array]:
    """Pre-processor + exponent unit: float z -> (e, m) fields of exp(z-zmax)."""
    z_raw = nm.fp2fx(z, cfg.frac_bits, cfg.total_bits)
    zmax_raw = strided_max(z_raw, cfg.step)
    d = z_raw - zmax_raw
    return nm.exp_unit(d, cfg.frac_bits, cfg.mant_bits)


def hyft_softmax_fwd(z: jax.Array, cfg: HyftConfig) -> jax.Array:
    """Forward Hyft softmax along the last axis."""
    e, m = hyft_exp_fields(z.astype(F32), cfg)
    addend = nm.expfloat_to_fx(e, m, cfg.mant_bits, cfg.acc_bits)
    denom = jnp.sum(addend, axis=-1, keepdims=True)
    e_b, m_b = nm.lod_refloat(denom, cfg.mant_bits)
    out = nm.log_div(e, m, e_b, m_b, cfg.mant_bits)
    return out.astype(cfg.dtype)


# --------------------------------------------------------------------------
# backward (paper §3.5: reuse of the div/mul unit + adder tree)
# --------------------------------------------------------------------------


def hyft_softmax_bwd(s: jax.Array, dy: jax.Array, cfg: HyftConfig) -> jax.Array:
    """dz = s * (dy - <dy, s>) with Hyft's approximate arithmetic.

    Each product runs through the log-domain multiplier with the half-range
    mantissa (Eq. 10); the dot product reuses the (signed) fixed-point adder
    tree; the final elementwise product reuses the multiplier again.
    """
    s32, dy32 = s.astype(F32), dy.astype(F32)
    prods = nm.log_mul(dy32, s32, cfg.mant_bits, half_range=True)
    prods_q = nm.fx_quantize(prods, cfg.bwd_acc_bits)
    dot = jnp.sum(prods_q, axis=-1, keepdims=True)
    diff = nm.fx_quantize(dy32, cfg.bwd_acc_bits) - dot  # exact fx subtract
    dz = nm.log_mul(diff, s32, cfg.mant_bits, half_range=True)
    return dz.astype(cfg.dtype)


# --------------------------------------------------------------------------
# public op with custom VJP
# --------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def hyft_softmax(z: jax.Array, cfg: HyftConfig = HYFT32) -> jax.Array:
    """Hyft softmax over the last axis, differentiable.

    The VJP is the accelerator's own backward path when ``cfg.grad="hyft"``
    (the paper's training mode), or the exact softmax VJP for ablations.
    """
    return hyft_softmax_fwd(z, cfg)


def _fwd(z, cfg):
    s = hyft_softmax_fwd(z, cfg)
    return s, (s, jnp.zeros((0,), z.dtype))  # carry primal dtype for the VJP


def _bwd(cfg, res, dy):
    s, dt_marker = res
    if cfg.grad == "exact":
        s32, dy32 = s.astype(F32), dy.astype(F32)
        dz = s32 * (dy32 - jnp.sum(dy32 * s32, axis=-1, keepdims=True))
        return (dz.astype(dt_marker.dtype),)
    return (hyft_softmax_bwd(s, dy, cfg).astype(dt_marker.dtype),)


hyft_softmax.defvjp(_fwd, _bwd)


def hyft_jacobian(s: jax.Array, cfg: HyftConfig = HYFT32) -> jax.Array:
    """Full Jacobian  ds/dz = diag(s) - s s^T  (paper Eq. 5), via log_mul.

    Exposed for the paper-faithful N x N backward block; the VJP above is the
    matrix-free form used in training.
    """
    s32 = s.astype(F32)
    outer = nm.log_mul(s32[..., :, None], s32[..., None, :], cfg.mant_bits)
    diag = jnp.eye(s.shape[-1], dtype=F32) * s32[..., None, :]
    return (diag - outer).astype(cfg.dtype)
