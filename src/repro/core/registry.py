"""Softmax-implementation registry: one string selects the softmax everywhere.

Models take ``softmax_impl: str`` in their config; attention blocks and MoE
routers resolve it here.  ``hyft16/hyft32/hyft16b`` run the paper's
accelerator emulation (differentiable, with the accelerator's own backward);
``hyft*_kernel`` route through the Pallas kernels (interpret mode on CPU);
the rest are baselines for the paper's comparison tables.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax

from repro.core import baselines
from repro.core.hyft import HYFT16, HYFT16B, HYFT32, HyftConfig, hyft_softmax


def _hyft(cfg: HyftConfig) -> Callable[[jax.Array], jax.Array]:
    def fn(z: jax.Array) -> jax.Array:
        return hyft_softmax(z, cfg).astype(z.dtype)
    return fn


def _hyft_kernel(cfg: HyftConfig) -> Callable[[jax.Array], jax.Array]:
    def fn(z: jax.Array) -> jax.Array:
        from repro.kernels import ops  # deferred: kernels are optional
        return ops.hyft_softmax(z, cfg).astype(z.dtype)
    return fn


_REGISTRY: dict[str, Callable[[jax.Array], jax.Array]] = {
    "exact": baselines.BASELINES["exact"],
    "base2": baselines.BASELINES["base2"],
    "koca": baselines.BASELINES["koca"],
    "lut8": baselines.BASELINES["lut8"],
    "softermax": baselines.BASELINES["softermax"],
    "hyft16": _hyft(HYFT16),
    "hyft32": _hyft(HYFT32),
    "hyft16b": _hyft(HYFT16B),
    "hyft16_kernel": _hyft_kernel(HYFT16),
    "hyft32_kernel": _hyft_kernel(HYFT32),
}


def get_softmax(name: str) -> Callable[[jax.Array], jax.Array]:
    """Resolve a softmax implementation by name (last-axis softmax)."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown softmax impl {name!r}; have {sorted(_REGISTRY)}")


def register_softmax(name: str, fn: Callable[[jax.Array], jax.Array]) -> None:
    _REGISTRY[name] = fn


def available() -> list[str]:
    return sorted(_REGISTRY)


def hyft_config_for(name: str) -> HyftConfig | None:
    return {
        "hyft16": HYFT16, "hyft32": HYFT32, "hyft16b": HYFT16B,
        "hyft16_kernel": HYFT16, "hyft32_kernel": HYFT32,
    }.get(name)
