"""Core: the paper's contribution — Hyft hybrid-format softmax (fwd + bwd)."""
from repro.core.hyft import (  # noqa: F401
    HYFT16,
    HYFT16B,
    HYFT32,
    HyftConfig,
    hyft_jacobian,
    hyft_softmax,
    hyft_softmax_bwd,
    hyft_softmax_fwd,
)
from repro.core.registry import available, get_softmax, register_softmax  # noqa: F401
