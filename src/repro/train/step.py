"""Train-step factory: grad accumulation, clipping, schedule, optimizer.

``build_train_step`` returns a jit'd (state, batch) -> (state, metrics) with
explicit in/out shardings and donated state.  Microbatch gradient
accumulation is a ``lax.scan`` over the leading batch split — activation
memory scales with the microbatch while the gradient reduce overlaps with
the next microbatch's compute (XLA pipelines the scan body).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import optim
from repro.configs.base import TrainConfig
from repro.optim.schedules import SCHEDULES

F32 = jnp.float32


def make_loss_fn(model, tcfg: TrainConfig):
    # attention-mode override: "kernel" trains through the fused Pallas
    # fwd+bwd kernels (custom_vjp on flash_hyft_attention)
    from repro.models import resolve_attn_mode
    model = resolve_attn_mode(model, getattr(tcfg, "attn_mode", None))

    def loss_fn(params, batch):
        return model.loss(params, batch, remat=tcfg.remat, z_loss=tcfg.z_loss,
                          moe_aux_weight=tcfg.moe_aux_weight)
    return loss_fn


def make_step_fn(model, tcfg: TrainConfig, opt_cfg: optim.OptConfig):
    loss_fn = make_loss_fn(model, tcfg)
    schedule = SCHEDULES.get("warmup_cosine")

    def grads_of(params, batch):
        with jax.named_scope("fwd_bwd"):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def step_fn(state, batch):
        params = state["params"]
        # static: _batch_dim reads .shape only (DESIGN.md #14 waiver)
        if tcfg.microbatch and tcfg.microbatch < _batch_dim(batch):  # lint: allow(traced-bool)
            n = _batch_dim(batch) // tcfg.microbatch
            micro = jax.tree.map(
                lambda x: x.reshape((n, tcfg.microbatch) + x.shape[1:]), batch)

            def body(acc, mb):
                loss, metrics, grads = grads_of(params, mb)
                acc_g, acc_l = acc
                return (jax.tree.map(jnp.add, acc_g, grads), acc_l + loss), metrics
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
            (grads, loss), metrics = jax.lax.scan(body, (zero, jnp.zeros((), F32)), micro)
            grads = jax.tree.map(lambda g: g / n, grads)
            loss = loss / n
            metrics = jax.tree.map(lambda m: jnp.mean(m), metrics)
        else:
            loss, metrics, grads = grads_of(params, batch)

        with jax.named_scope("optimizer"):
            grads, gnorm = optim.clip_by_global_norm(grads, tcfg.grad_clip)
            lr_scale = schedule(state["step"], warmup=tcfg.warmup_steps,
                                total=tcfg.total_steps)
            new_params, new_opt = optim.update(opt_cfg, grads, state["opt"],
                                               params, lr_scale=lr_scale)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1,
                     "rng": jax.random.fold_in(state["rng"], 1)}
        out_metrics = {"loss": loss, "grad_norm": gnorm, "lr_scale": lr_scale,
                       **metrics}
        return new_state, out_metrics

    return step_fn


def _batch_dim(batch) -> int:
    return jax.tree.leaves(batch)[0].shape[0]


def build_train_step(model, tcfg: TrainConfig, opt_cfg, mesh, state_sh,
                     batch_sh):
    """jit with explicit shardings + state donation."""
    step_fn = make_step_fn(model, tcfg, opt_cfg)
    rep = NamedSharding(mesh, P())
    metric_sh = None  # let the compiler place scalars
    return jax.jit(step_fn,
                   in_shardings=(state_sh, batch_sh),
                   out_shardings=(state_sh, metric_sh),
                   donate_argnums=(0,))
