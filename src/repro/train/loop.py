"""Training loop: checkpoint/restart, straggler monitoring, metrics."""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax

from repro.checkpoint import checkpointer
from repro.configs.base import TrainConfig
from repro.distributed.fault_tolerance import RestartManager, StragglerMonitor


def run_train(state, train_step, batch_fn: Callable[[int], dict],
              tcfg: TrainConfig, ckpt_dir: Optional[str] = None,
              state_sh=None, log_every: int = 10,
              fail_at: Optional[Callable[[int], None]] = None,
              log_fn=print) -> tuple[dict, list]:
    """Run the loop with fault tolerance. ``fail_at`` injects faults (tests).

    Returns (final state, metric history).  If ``ckpt_dir`` is set the loop
    is supervised by RestartManager: any exception reloads the latest atomic
    checkpoint and resumes (deterministic data stream keyed by step).
    """
    history: list = []
    monitor = StragglerMonitor()
    state_box = {"state": state}

    def body(start_step: int) -> int:
        if ckpt_dir and checkpointer.latest_step(ckpt_dir) is not None:
            st, step0 = checkpointer.restore(
                ckpt_dir, checkpointer.latest_step(ckpt_dir),
                jax.eval_shape(lambda: state_box["state"]), shardings=state_sh)
            state_box["state"] = st
            start_step = step0
        for step in range(start_step, tcfg.total_steps):
            if fail_at is not None:
                fail_at(step)  # may raise (fault injection)
            t0 = time.monotonic()
            batch = batch_fn(step)
            state_box["state"], metrics = train_step(state_box["state"], batch)
            if step % log_every == 0 or step == tcfg.total_steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                history.append({"step": step, **m})
                log_fn(f"step {step:5d} " +
                       " ".join(f"{k}={v:.4f}" for k, v in m.items()))
            dt = time.monotonic() - t0
            if monitor.observe(dt):
                log_fn(f"[straggler] step {step} took {dt:.3f}s "
                       f"(ema {monitor.ema:.3f}s)")
            if ckpt_dir and (step + 1) % tcfg.checkpoint_every == 0:
                checkpointer.save(ckpt_dir, step + 1, state_box["state"],
                                  keep=tcfg.keep_checkpoints)
        return tcfg.total_steps

    if ckpt_dir:
        RestartManager(ckpt_dir).run(body)
    else:
        body(0)
    return state_box["state"], history
