from repro.train.loop import run_train  # noqa: F401
from repro.train.state import abstract_state, init_state, state_shardings  # noqa: F401
from repro.train.step import build_train_step, make_loss_fn, make_step_fn  # noqa: F401
