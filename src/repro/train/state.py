"""Train state construction + sharding derivation."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import optim
from repro.distributed import sharding as shd
from repro.models.layers import unbox


def init_state(model, opt_cfg: optim.OptConfig, key):
    boxed = model.init(key)
    params = unbox(boxed)
    return {"params": params, "opt": optim.init(opt_cfg, params),
            "step": jnp.zeros((), jnp.int32), "rng": jax.random.PRNGKey(0)}


def abstract_state(model, opt_cfg: optim.OptConfig):
    """eval_shape twin of init_state (no allocation) + boxed axes tree."""
    boxed = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    state = jax.eval_shape(
        lambda: init_state(model, opt_cfg, jax.random.PRNGKey(0)))
    return state, boxed


def state_shardings(mesh, model, opt_cfg: optim.OptConfig, rules):
    """NamedSharding pytree matching init_state's structure."""
    state_shape, boxed = abstract_state(model, opt_cfg)
    psh = shd.param_shardings(mesh, boxed, rules)
    pshapes = jax.tree.map(lambda x: x.shape, state_shape["params"])

    def _padded(s: NamedSharding, rank: int) -> list:
        spec = list(s.spec)
        return spec + [None] * (rank - len(spec))

    def reduce_last(s: NamedSharding, shape):
        # adafactor vr: params of rank >= 2 lose the last dim; 1-D params
        # keep their shape (vr == zeros_like) and their sharding
        if len(shape) < 2:
            return s
        return NamedSharding(mesh, P(*_padded(s, len(shape))[:-1]))

    def reduce_second_last(s: NamedSharding, shape):
        if len(shape) < 2:
            return NamedSharding(mesh, P())  # vc is a zero-size stub
        spec = _padded(s, len(shape))
        return NamedSharding(mesh, P(*(spec[:-2] + spec[-1:])))

    opt_sh = {}
    for k in state_shape["opt"]:
        if k == "step":
            opt_sh[k] = NamedSharding(mesh, P())
        elif k == "vr":
            opt_sh[k] = jax.tree.map(reduce_last, psh, pshapes)
        elif k == "vc":
            opt_sh[k] = jax.tree.map(reduce_second_last, psh, pshapes)
        else:  # master / m / v / mom mirror the params
            opt_sh[k] = psh
    rep = NamedSharding(mesh, P())
    return {"params": psh, "opt": opt_sh, "step": rep, "rng": rep}
