"""Typed metrics registry: counters, gauges, and streaming-percentile
histograms (DESIGN.md §15).

The histogram is a log-bucketed sketch (growth factor 1.05 → ≤ ~2.5%
relative error on percentiles) with exact count/sum/min/max, so totals
always reconcile exactly even though percentiles are approximate.  Buckets
are a sparse dict — observing is one ``math.log`` + dict increment, cheap
enough for per-token TBT observations.

Metrics are keyed by (name, sorted label items); ``Registry.counter(name,
**labels)`` is get-or-create, so read paths (e.g. the scheduler's
back-compat ``stats`` view) can query without pre-registration.
"""
from __future__ import annotations

import json
import math
import os
import time
from typing import Dict, Iterable, List, Optional, Tuple

_GROWTH = 1.05
_LG = math.log(_GROWTH)
_FLOOR = 1e-9  # observations <= _FLOOR land in the underflow bucket


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v

    def track_max(self, v: float) -> None:
        if v > self.value:
            self.value = v


class Histogram:
    __slots__ = ("count", "total", "vmin", "vmax", "_under", "_buckets")

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self._under = 0
        self._buckets: Dict[int, int] = {}

    def observe(self, v: float) -> None:
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v
        if v <= _FLOOR:
            self._under += 1
            return
        idx = int(math.log(v / _FLOOR) / _LG)
        self._buckets[idx] = self._buckets.get(idx, 0) + 1

    def percentile(self, q: float) -> Optional[float]:
        """Approximate q-th percentile (q in [0, 100]); None on an empty
        histogram — there is no value to report, and 0.0 reads as a real
        (excellent) latency downstream."""
        if self.count == 0:
            return None
        rank = q / 100.0 * (self.count - 1)
        seen = self._under
        if rank < seen:
            return self.vmin
        for idx in sorted(self._buckets):
            seen += self._buckets[idx]
            if rank < seen:
                # geometric midpoint of the bucket, clamped to exact extremes
                v = _FLOOR * _GROWTH ** (idx + 0.5)
                return min(max(v, self.vmin), self.vmax)
        return self.vmax

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def summary(self) -> dict:
        # percentiles are None when empty (see ``percentile``); count/sum
        # stay numeric so totals always reconcile
        return {
            "count": self.count, "sum": self.total,
            "min": self.vmin if self.count else 0.0,
            "max": self.vmax if self.count else 0.0,
            "mean": self.mean, "p50": self.percentile(50),
            "p90": self.percentile(90), "p99": self.percentile(99),
        }


def _key(name: str, labels: dict) -> Tuple[str, Tuple[Tuple[str, str], ...]]:
    return name, tuple(sorted(labels.items()))


class Registry:
    """Get-or-create metric store.  A name is bound to one kind; mixing
    kinds under one name raises."""

    def __init__(self):
        self._metrics: Dict[Tuple, object] = {}
        self._kinds: Dict[str, type] = {}
        self._snapshots: List[str] = []  # JSONL lines already exported

    def _get(self, cls, name: str, labels: dict):
        bound = self._kinds.setdefault(name, cls)
        if bound is not cls:
            raise TypeError(
                f"metric {name!r} already registered as {bound.__name__}")
        k = _key(name, labels)
        m = self._metrics.get(k)
        if m is None:
            m = self._metrics[k] = cls()
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(Histogram, name, labels)

    def items(self) -> Iterable[Tuple[str, dict, object]]:
        for (name, litems), m in sorted(self._metrics.items()):
            yield name, dict(litems), m

    def snapshot(self) -> dict:
        """One JSON-ready snapshot of every metric."""
        out: List[dict] = []
        for name, labels, m in self.items():
            row = {"name": name, "labels": labels,
                   "kind": type(m).__name__.lower()}
            if isinstance(m, Histogram):
                row.update(m.summary())
            else:
                row["value"] = m.value
            out.append(row)
        return {"ts": time.time(), "metrics": out}

    def write_jsonl(self, path: str) -> None:
        """Append one snapshot line (JSONL export), atomically: the full
        snapshot history is rewritten to a temp file and renamed over the
        target, so a crash mid-export (or a concurrent reader) never sees
        a torn line."""
        self._snapshots.append(json.dumps(self.snapshot()))
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            f.write("\n".join(self._snapshots) + "\n")
        os.replace(tmp, path)

    def report(self) -> str:
        """End-of-run text report."""
        lines = []
        for name, labels, m in self.items():
            ltxt = ",".join(f"{k}={v}" for k, v in labels.items())
            ltxt = "{" + ltxt + "}" if ltxt else ""
            if isinstance(m, Histogram):
                s = m.summary()
                if s["count"] == 0:  # percentiles are None when empty
                    lines.append(f"{name}{ltxt} count=0")
                    continue
                lines.append(
                    f"{name}{ltxt} count={s['count']} mean={s['mean']:.4g} "
                    f"p50={s['p50']:.4g} p90={s['p90']:.4g} "
                    f"p99={s['p99']:.4g} max={s['max']:.4g}")
            elif isinstance(m, Gauge):
                lines.append(f"{name}{ltxt} {m.value:.6g}")
            else:
                lines.append(f"{name}{ltxt} {m.value}")
        return "\n".join(lines)

    def find(self, name: str, **labels) -> Optional[object]:
        """Lookup without creating (for tests / reconciliation)."""
        return self._metrics.get(_key(name, labels))
