"""Benchmark regression ledger (DESIGN.md §16).

Every bench run (softmax / decode / serve / kernels) appends one JSONL row
to ``BENCH_ledger.jsonl``, keyed by git SHA with full provenance — backend,
device kind, Pallas interpret flag, jax version, host, timestamp, and the
run mode (full vs smoke) — so the CPU interpreter-mode numbers can never
masquerade as hardware results and the bench trajectory becomes a guarded
time series.  ``scripts/check.py --bench-regress`` compares the current
BENCH_*.json artifacts against the committed baseline rows.

Tolerance policy (one of three kinds per metric, applied by ``compare``):

  exact — booleans and counts (output equality, chaos definiteness, kernel
          coverage): any change is a regression.
  ratio — machine-portable relative metrics (speedups, acceptance/hit
          rates): compared whenever backend/device/interpret/mode match;
          tolerances are generous because scheduler ratios still carry
          wall-clock arrival timing.
  wall  — absolute times and rates (us_per_call, tokens/sec): compared
          only when the baseline row comes from the SAME host, since
          absolute CPU numbers do not transfer between machines.

Only degradation beyond ``rel_tol`` fails; improvements never do.  The
baseline for a run is the newest matching row strictly older than the
run's own; with no older row the run is compared against its own appended
row — a schema/extraction consistency check rather than a trend check —
so a freshly committed baseline always passes and the first CI run after
it gets a real cross-run comparison.
"""
from __future__ import annotations

import dataclasses
import json
import os
import platform
import subprocess
import time
from typing import Dict, List, Optional

from repro.analysis.common import Finding

LEDGER = "BENCH_ledger.jsonl"
PROVENANCE_KEYS = ("backend", "device_kind", "interpret", "jax_version",
                   "git_sha", "host", "ts", "mode")
# a baseline row must match the current run on these to be comparable at all
_MATCH_KEYS = ("backend", "device_kind", "interpret", "mode")


def git_sha(root: Optional[str] = None) -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=root,
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def provenance(mode: str = "full", root: Optional[str] = None) -> dict:
    import jax

    from repro.kernels.ops import _auto_interpret
    dev = jax.devices()[0]
    return {"backend": jax.default_backend(),
            "device_kind": dev.device_kind,
            "interpret": bool(_auto_interpret()),
            "jax_version": jax.__version__,
            "git_sha": git_sha(root),
            "host": platform.node(),
            "ts": time.time(),
            "mode": mode}


@dataclasses.dataclass(frozen=True)
class Metric:
    """One guarded bench metric: its value plus the policy ``compare``
    applies (rows persist values only — policy lives in code, so a
    tolerance fix applies retroactively to the whole history)."""
    name: str
    value: float
    kind: str               # "exact" | "ratio" | "wall"
    direction: str = "higher"   # which way is better
    rel_tol: float = 0.5


def _op_metrics(results: dict) -> List[Metric]:
    out = []
    for r in results.get("op", []):
        key = f"op.{r['mode']}.{r['shape']}"
        out.append(Metric(f"{key}.us_per_step", r["us_per_step"],
                          "wall", "lower"))
        if r["mode"] != "unfused":
            # time ratio vs the unfused baseline on the same host/run:
            # machine-portable-ish, but interpreter variance is real
            out.append(Metric(f"{key}.vs_unfused", r["vs_unfused"],
                              "ratio", "lower", 1.0))
    rows = {(r["loop"], r["cache"]): r for r in results.get("e2e", [])}
    for (loop, cache), r in rows.items():
        out.append(Metric(f"e2e.{loop}.{cache}.tokens_per_s",
                          r["tokens_per_s"], "wall", "higher"))
    host = rows.get(("host", "float32"))
    scan = rows.get(("scan", "float32"))
    if host and scan:
        out.append(Metric("e2e.scan_vs_host",
                          host["us_per_token"] / scan["us_per_token"],
                          "ratio", "higher", 0.6))
    return out


def _serve_metrics(results: dict) -> List[Metric]:
    out = []
    for key in ("continuous_vs_lockstep", "paged_prefix_vs_dense",
                "spec_vs_baseline", "whole_prompt_vs_chunked_tbt_p99"):
        if key in results:
            out.append(Metric(key, float(results[key]), "ratio", "higher",
                              0.6))
    if "chunked_outputs_equal" in results:
        out.append(Metric("chunked_outputs_equal",
                          float(bool(results["chunked_outputs_equal"])),
                          "exact"))
    for section in ("engines", "prefix_engines", "spec_engines",
                    "chunked_engines"):
        for name, r in results.get(section, {}).items():
            out.append(Metric(f"{section}.{name}.tokens_per_s",
                              r["tokens_per_s"], "wall", "higher"))
    spec = results.get("spec_engines", {}).get("spec")
    if spec and "acceptance_rate" in spec:
        out.append(Metric("spec.acceptance_rate", spec["acceptance_rate"],
                          "ratio", "higher", 0.3))
    for name, r in results.get("chaos", {}).get("configs", {}).items():
        out.append(Metric(f"chaos.{name}.definite", float(r["definite"]),
                          "exact"))
        out.append(Metric(f"chaos.{name}.outputs_match",
                          float(r["outputs_match"]), "exact"))
    return out


def _kernel_metrics(results: dict) -> List[Metric]:
    rows = results.get("kernels", [])
    out = [Metric("kernels.count", float(len(rows)), "exact")]
    for r in rows:
        out.append(Metric(f"kernels.{r['kernel']}.us_per_call",
                          r["us_per_call"], "wall", "lower"))
    return out


def _softmax_metrics(results: dict) -> List[Metric]:
    out = []
    for r in results.get("softmax", []):
        key = f"softmax.{r['impl']}.{r['shape']}"
        out.append(Metric(f"{key}.us_per_call", r["us_per_call"],
                          "wall", "lower"))
        if r["impl"] != "exact":
            out.append(Metric(f"{key}.vs_exact", r["vs_exact"],
                              "ratio", "lower", 1.0))
    return out


_EXTRACTORS = {"decode": _op_metrics, "serve": _serve_metrics,
               "kernels": _kernel_metrics, "softmax": _softmax_metrics}

# (bench key, artifact filename) — the files ``regress`` audits
BENCH_FILES = (("softmax", "BENCH_softmax.json"),
               ("decode", "BENCH_decode.json"),
               ("serve", "BENCH_serve.json"),
               ("kernels", "BENCH_kernels.json"))


def extract(bench: str, results: dict) -> List[Metric]:
    """The guarded metrics of one bench's results dict."""
    fn = _EXTRACTORS.get(bench)
    return fn(results) if fn else []


def load(path: str) -> List[dict]:
    if not os.path.exists(path):
        return []
    rows = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                rows.append(json.loads(line))
    return rows


def append(path: str, bench: str, results: dict,
           prov: Optional[dict] = None) -> dict:
    """Append one ledger row for ``results`` (uses the artifact's own
    provenance stamp when present).  The ledger is append-only JSONL —
    history is the point."""
    prov = prov or results.get("provenance") or provenance()
    row = {"bench": bench, "provenance": prov,
           "metrics": {m.name: m.value for m in extract(bench, results)}}
    with open(path, "a") as f:
        f.write(json.dumps(row) + "\n")
    return row


def baseline_for(rows: List[dict], bench: str,
                 prov: dict) -> Optional[dict]:
    """Newest matching row strictly older than ``prov``; falls back to
    the newest matching row (the run's own) when no older one exists."""
    cand = [r for r in rows if r.get("bench") == bench
            and all(r.get("provenance", {}).get(k) == prov.get(k)
                    for k in _MATCH_KEYS)]
    cand.sort(key=lambda r: r.get("provenance", {}).get("ts", 0.0))
    older = [r for r in cand
             if r.get("provenance", {}).get("ts", 0.0) < prov.get("ts", 0.0)]
    if older:
        return older[-1]
    return cand[-1] if cand else None


def compare(baseline_row: dict, metrics: List[Metric],
            prov: dict, bench: str = "") -> List[Finding]:
    """Per-metric tolerance comparison of a current run against one
    baseline row.  Metrics absent from the baseline are skipped (new
    metrics enter the guard on the next append)."""
    base: Dict[str, float] = baseline_row.get("metrics", {})
    bprov = baseline_row.get("provenance", {})
    same_host = bprov.get("host") == prov.get("host")
    where = f"{bench}:" if bench else ""
    out: List[Finding] = []
    for m in metrics:
        if m.name not in base:
            continue
        b = float(base[m.name])
        if m.kind == "exact":
            if m.value != b:
                out.append(Finding(
                    "bench", "regress.exact", where + m.name,
                    f"expected {b:g} (sha {bprov.get('git_sha')}), "
                    f"got {m.value:g}"))
            continue
        if m.kind == "wall" and not same_host:
            continue  # absolute CPU numbers do not transfer across hosts
        if b <= 0:
            continue
        deg = ((b - m.value) if m.direction == "higher"
               else (m.value - b)) / b
        if deg > m.rel_tol:
            out.append(Finding(
                "bench", f"regress.{m.kind}", where + m.name,
                f"{b:.4g} -> {m.value:.4g} "
                f"({deg:+.0%} worse than sha {bprov.get('git_sha')}, "
                f"tolerance {m.rel_tol:.0%})"))
    return out


def regress(root: str = ".", ledger_path: Optional[str] = None,
            report=print) -> List[Finding]:
    """The ``scripts/check.py --bench-regress`` pass: every BENCH_*.json
    under ``root`` is extracted and compared against its ledger baseline.
    A missing artifact is skipped; an artifact without a provenance stamp
    is a finding (satellite contract: interpreter numbers must carry
    their provenance)."""
    rows = load(ledger_path or os.path.join(root, LEDGER))
    findings: List[Finding] = []
    for bench, fname in BENCH_FILES:
        path = os.path.join(root, fname)
        if not os.path.exists(path):
            continue
        with open(path) as f:
            results = json.load(f)
        prov = results.get("provenance")
        if not prov:
            findings.append(Finding(
                "bench", "regress.no-provenance", fname,
                "artifact has no provenance stamp -- regenerate with the "
                "current bench harness"))
            continue
        metrics = extract(bench, results)
        base = baseline_for(rows, bench, prov)
        if base is None:
            report(f"[bench-regress] {bench}: no matching baseline row "
                   f"(mode={prov.get('mode')}) -- skipped")
            continue
        fs = compare(base, metrics, prov, bench=bench)
        bp = base.get("provenance", {})
        tag = ("self-row" if bp.get("ts") == prov.get("ts")
               else f"sha {bp.get('git_sha')}")
        report(f"[bench-regress] {bench}: {len(metrics)} metric(s) vs "
               f"{tag}: {len(fs)} regression(s)")
        findings += fs
    return findings


def finalize(json_path: str, bench: str, results: dict, mode: str = "full",
             ledger_path: Optional[str] = "auto") -> dict:
    """Bench ``__main__`` epilogue: stamp provenance into ``results``,
    write the artifact, append the ledger row.  ``ledger_path="auto"``
    puts the ledger next to the artifact; None skips the append."""
    results = dict(results)
    results["provenance"] = provenance(mode)
    with open(json_path, "w") as f:
        json.dump(results, f, indent=2)
    if ledger_path == "auto":
        ledger_path = os.path.join(
            os.path.dirname(os.path.abspath(json_path)) or ".", LEDGER)
    if ledger_path:
        append(ledger_path, bench, results)
    return results
