"""Hybrid-format telemetry (DESIGN.md §15): device-side per-burst numeric
stats plus the host-side accumulator that folds them into a run summary.

This is the Hyft-specific observability pillar — the paper's claim is that
hybrid fp/fixed formats hold accuracy *because* the realized dynamic range
of softmax inputs (post max-subtraction) and KV rows is narrow; these
functions measure that range at runtime:

  logit_stats / reduce_logit_stats
      running exponent range of softmax/sampling inputs pre and post
      max-subtraction, computed inside the jitted burst at the cost of a
      few row reductions per step (a NaN-poisoned burst propagates NaN
      into z_max, which is exactly the explanation the quarantine wants)
  format_stats
      fp2fx8 KV telemetry from the final burst cache: int8 saturation
      counts (|raw| == 127, the clip level of fp2fx8_quantize) and a
      64-bin power-of-two histogram of the per-row scales (only written
      rows — scale 0 means an untouched position, e.g. unallocated pages)
  NumericsMonitor
      host accumulator: one small device→host sync per burst when
      ``ServeConfig.telemetry`` is on, keeps the most recent burst's stats
      (``last``) so quarantine decisions can be annotated with the numbers
      that triggered them, and counts fp→fx convert volume at the §14
      format boundaries (KV quantize on write).

Everything in the jit-side functions is shape-static: the returned pytree
structure depends only on the cache structure, so it is a valid jit output.
"""
from __future__ import annotations

import math
from typing import Dict, List

import numpy as np

import jax
import jax.numpy as jnp

F32 = jnp.float32
SCALE_BINS = 64
# bin = floor(log2(scale)) + offset, clipped to [0, SCALE_BINS); offset 40
# centres the fp2fx8 regime (scales ~2^-12..2^-2 for unit-variance KV)
SCALE_BIN_OFFSET = 40
_INT8_SAT = 127  # |raw| at the fp2fx8_quantize clip level


def logit_stats(logits, active):
    """Per-step exponent-range stats of the sampling logits.

    logits: (B, V) float, active: (B,) bool.  Returns a (3,) f32 vector
    [z_max, z_min, zsub_min] over active rows, where zsub_min is the
    minimum of (z - max(z)) — the post-max-subtraction softmax input range.
    Inactive rows contribute neutral values; NaNs propagate (by design).
    """
    x = logits.astype(F32)
    row_max = jnp.max(x, axis=-1)
    row_min = jnp.min(x, axis=-1)
    sub_min = row_min - row_max
    neg = F32(-jnp.inf)
    pos = F32(jnp.inf)
    z_max = jnp.max(jnp.where(active, row_max, neg))
    z_min = jnp.min(jnp.where(active, row_min, pos))
    zs_min = jnp.min(jnp.where(active, sub_min, pos))
    return jnp.stack([z_max, z_min, zs_min])


def reduce_logit_stats(per_step):
    """Reduce stacked (T, 3) per-step stats to one burst dict."""
    return {
        "z_max": jnp.max(per_step[:, 0]),
        "z_min": jnp.min(per_step[:, 1]),
        "zsub_min": jnp.min(per_step[:, 2]),
    }


def _leaf_name(path) -> str:
    name = ""
    for p in path:
        key = getattr(p, "key", None)
        if isinstance(key, str):
            name = key
    return name


def format_stats(cache) -> Dict[str, jnp.ndarray]:
    """fp2fx8 KV telemetry over a cache pytree (jit-safe).

    int8 leaves feed the saturation count; ``*_scale`` leaves feed the
    power-of-two scale histogram and min/max (zero scales = unwritten
    positions, skipped).  Returns {} for unquantized caches — the pytree
    structure is static per cache structure, so jit is happy either way.
    """
    sat = jnp.zeros((), jnp.int32)
    hist = jnp.zeros((SCALE_BINS,), F32)
    smin = F32(jnp.inf)
    smax = F32(0.0)
    quantized = False
    for path, leaf in jax.tree_util.tree_leaves_with_path(cache):
        name = _leaf_name(path)
        if leaf.dtype == jnp.int8:
            quantized = True
            sat = sat + jnp.sum(
                (jnp.abs(leaf.astype(jnp.int32)) >= _INT8_SAT)
                .astype(jnp.int32))
        elif name.endswith("_scale"):
            s = leaf.astype(F32).reshape(-1)
            written = s > 0
            e = jnp.clip(
                jnp.floor(jnp.log2(jnp.maximum(s, F32(1e-45))))
                .astype(jnp.int32) + SCALE_BIN_OFFSET, 0, SCALE_BINS - 1)
            hist = hist + jnp.bincount(
                e, weights=written.astype(F32), length=SCALE_BINS)
            smin = jnp.minimum(
                smin, jnp.min(jnp.where(written, s, F32(jnp.inf))))
            smax = jnp.maximum(smax, jnp.max(s))
    if not quantized:
        return {}
    return {"kv_saturated": sat, "kv_scale_hist": hist,
            "kv_scale_min": smin, "kv_scale_max": smax}


def int8_size(cache) -> int:
    """Host-side static count of int8 cache elements (saturation base)."""
    return sum(int(leaf.size) for leaf in jax.tree_util.tree_leaves(cache)
               if hasattr(leaf, "dtype") and leaf.dtype == jnp.int8)


class NumericsMonitor:
    """Host accumulator for per-burst telemetry dicts."""

    def __init__(self):
        self.bursts = 0
        self.z_max = -math.inf
        self.z_min = math.inf
        self.zsub_min = math.inf
        self.kv_saturated = 0
        self.kv_int8_total = 0
        self.kv_scale_hist = np.zeros(SCALE_BINS, dtype=np.int64)
        self.kv_scale_min = math.inf
        self.kv_scale_max = 0.0
        self.converts = 0
        self.last: Dict[str, float] = {}
        self.quarantine_events: List[dict] = []

    def update(self, tstats) -> Dict[str, float]:
        """Fold one burst's device stats dict; returns the host-side
        scalars for this burst (also kept as ``self.last``)."""
        if not tstats:
            return {}
        d = {k: np.asarray(v) for k, v in tstats.items()}
        self.bursts += 1
        last: Dict[str, float] = {}
        if "z_max" in d:
            zmax = float(d["z_max"])
            zmin = float(d["z_min"])
            zsub = float(d["zsub_min"])
            last.update(z_max=zmax, z_min=zmin, zsub_min=zsub)
            # NaN-poisoned bursts leave the running range untouched but
            # stay visible in ``last`` (and hence quarantine annotations)
            if math.isfinite(zmax):
                self.z_max = max(self.z_max, zmax)
            if math.isfinite(zmin):
                self.z_min = min(self.z_min, zmin)
            if math.isfinite(zsub):
                self.zsub_min = min(self.zsub_min, zsub)
        if "kv_saturated" in d:
            sat = int(d["kv_saturated"])
            self.kv_saturated = sat  # cache-wide count, latest wins
            self.kv_scale_hist = d["kv_scale_hist"].astype(np.int64)
            smin = float(d["kv_scale_min"])
            smax = float(d["kv_scale_max"])
            if math.isfinite(smin):
                self.kv_scale_min = min(self.kv_scale_min, smin)
            self.kv_scale_max = max(self.kv_scale_max, smax)
            last.update(kv_saturated=sat, kv_scale_min=smin,
                        kv_scale_max=smax)
        self.last = last
        return last

    def add_converts(self, n: int) -> None:
        self.converts += int(n)

    def record_quarantine(self, rid, where: str) -> dict:
        """Annotate a quarantine decision with the most recent burst's
        numeric stats (the numbers that triggered the ladder)."""
        ev = {"rid": rid, "where": where, **self.last}
        self.quarantine_events.append(ev)
        return ev

    def summary(self) -> dict:
        def _f(v):
            return v if math.isfinite(v) else None

        out = {
            "bursts": self.bursts,
            "z_max": _f(self.z_max) if self.bursts else None,
            "z_min": _f(self.z_min) if self.bursts else None,
            "zsub_min": _f(self.zsub_min) if self.bursts else None,
            "converts": self.converts,
        }
        if self.kv_scale_hist.any() or self.kv_int8_total:
            nz = np.nonzero(self.kv_scale_hist)[0]
            out.update({
                "kv_saturated": self.kv_saturated,
                "kv_int8_total": self.kv_int8_total,
                "kv_saturation_rate": (
                    self.kv_saturated / self.kv_int8_total
                    if self.kv_int8_total else 0.0),
                "kv_scale_min": _f(self.kv_scale_min),
                "kv_scale_max": self.kv_scale_max,
                # sparse histogram: {exponent: count}, exponent = log2(scale)
                "kv_scale_hist": {
                    int(i - SCALE_BIN_OFFSET): int(self.kv_scale_hist[i])
                    for i in nz},
            })
        if self.quarantine_events:
            out["quarantine_events"] = list(self.quarantine_events)
        return out
