"""Host-side span tracer emitting Chrome trace-event JSON (DESIGN.md §15).

The output file loads directly in Perfetto (https://ui.perfetto.dev) or
chrome://tracing.  Three event kinds are used:

  "X"  complete event  — a span with ts+dur (microseconds), from
                         ``Tracer.span(...)`` used as a context manager
  "i"  instant event   — a point-in-time marker from ``Tracer.instant(...)``
  "C"  counter event   — a sampled value track from ``Tracer.counter(...)``

Overhead budget: a disabled tracer must cost one attribute check per span
(the CI obs-smoke job asserts < 5% tokens/sec overhead tracer-on vs
tracer-off, see .github/workflows/ci.yml).  Spans are plain dict appends —
no locks, no I/O until ``write()``.

``compile_watch`` turns XLA compile log lines into "compile" spans at
runtime: it is the same ``jax_log_compiles`` listener that
``analysis/retrace.py``'s RetraceGuard is built on (the regexes and the
logging plumbing live here; retrace.py layers its budget/steady-state
policy on top).
"""
from __future__ import annotations

import json
import logging
import math
import os
import re
import time
from typing import Any, Dict, List, Optional

# "Finished tracing + transforming <name> for ..." / "... in N sec" — the
# exact phrasing varies across jax versions, hence the permissive tails.
TRACE_RE = re.compile(r"Finished tracing \+ transforming (.+?) (?:for|in)\b")
COMPILE_RE = re.compile(r"Finished XLA compilation of (.+?) in\b")
_DUR_RE = re.compile(r"in ([0-9.eE+-]+) sec")


def _jsonable(o: Any):
    if hasattr(o, "tolist"):
        return o.tolist()
    return str(o)


def _finite(o: Any):
    """NaN/Inf are not valid JSON — stringify them (e.g. quarantine args
    carrying poisoned numeric stats) so the file stays Perfetto-parseable."""
    if isinstance(o, float) and not math.isfinite(o):
        return repr(o)
    if isinstance(o, dict):
        return {k: _finite(v) for k, v in o.items()}
    if isinstance(o, (list, tuple)):
        return [_finite(v) for v in o]
    return o


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("_tracer", "_name", "_cat", "_args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str, args: dict):
        self._tracer = tracer
        self._name = name
        self._cat = cat
        self._args = args

    def __enter__(self):
        self._t0 = self._tracer._clock()
        return self

    def __exit__(self, *exc):
        t1 = self._tracer._clock()
        self._tracer.events.append({
            "name": self._name, "ph": "X", "ts": self._t0 * 1e6,
            "dur": (t1 - self._t0) * 1e6, "pid": self._tracer._pid,
            "tid": 0, "cat": self._cat, "args": self._args,
        })
        return False


class Tracer:
    """Appends Chrome trace events to an in-memory list; ``write()`` dumps
    a Perfetto-loadable ``{"traceEvents": [...]}`` JSON file."""

    __slots__ = ("enabled", "events", "_pid", "_clock")

    def __init__(self, enabled: bool = True, clock=time.perf_counter):
        self.enabled = enabled
        self.events: List[Dict[str, Any]] = []
        self._pid = os.getpid()
        self._clock = clock

    def span(self, name: str, cat: str = "serve", **args):
        """Context manager recording an "X" complete event on exit."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, cat, args)

    def instant(self, name: str, cat: str = "serve", **args) -> None:
        if not self.enabled:
            return
        self.events.append({
            "name": name, "ph": "i", "ts": self._clock() * 1e6,
            "pid": self._pid, "tid": 0, "cat": cat, "s": "t", "args": args,
        })

    def counter(self, name: str, cat: str = "serve", **values) -> None:
        if not self.enabled:
            return
        self.events.append({
            "name": name, "ph": "C", "ts": self._clock() * 1e6,
            "pid": self._pid, "tid": 0, "cat": cat, "args": values,
        })

    def compile_span(self, name: str, dur_s: float, kind: str) -> None:
        """Backdated span ending now — compile durations arrive after the
        fact from the jax log stream."""
        if not self.enabled:
            return
        t1 = self._clock()
        self.events.append({
            "name": "compile", "ph": "X", "ts": (t1 - dur_s) * 1e6,
            "dur": dur_s * 1e6, "pid": self._pid, "tid": 1, "cat": "compile",
            "args": {"fn": name, "kind": kind},
        })

    def span_kinds(self) -> set:
        return {e["name"] for e in self.events}

    def write(self, path: str) -> None:
        events = [dict(e, args=_finite(e.get("args", {})))
                  for e in self.events]
        with open(path, "w") as f:
            json.dump({"traceEvents": events, "displayTimeUnit": "ms"},
                      f, default=_jsonable)


NULL_TRACER = Tracer(enabled=False)


class CompileListener(logging.Handler):
    """Collects jax trace/compile log lines; optionally stamps "compile"
    spans into a tracer as they happen."""

    def __init__(self, tracer: Optional[Tracer] = None):
        super().__init__(level=logging.DEBUG)
        self.traces: List[str] = []
        self.compiles: List[str] = []
        self.tracer = tracer

    def emit(self, record: logging.LogRecord) -> None:
        msg = record.getMessage()
        m = TRACE_RE.search(msg)
        if m:
            self.traces.append(m.group(1))
            if self.tracer is not None:
                dm = _DUR_RE.search(msg)
                self.tracer.compile_span(
                    m.group(1), float(dm.group(1)) if dm else 0.0, "trace")
            return
        m = COMPILE_RE.search(msg)
        if m:
            self.compiles.append(m.group(1))
            if self.tracer is not None:
                dm = _DUR_RE.search(msg)
                self.tracer.compile_span(
                    m.group(1), float(dm.group(1)) if dm else 0.0, "xla")


class compile_watch:
    """Context manager routing jax compile logs into a CompileListener.

    Flips ``jax_log_compiles`` on and pins the "jax" logger (level INFO,
    propagation off) for the duration, restoring everything on exit.
    ``compile_watch(tracer)`` with a disabled/None tracer still counts
    compiles (``.listener``); pass ``enabled=False`` to make it a no-op.
    Nesting is safe — each watch attaches its own handler.
    """

    def __init__(self, tracer: Optional[Tracer] = None,
                 enabled: bool = True):
        self.listener = CompileListener(
            tracer if tracer is not None and tracer.enabled else None)
        self._enabled = enabled
        self._logger = logging.getLogger("jax")

    def __enter__(self) -> "compile_watch":
        if not self._enabled:
            return self
        import jax
        self._flag = jax.config.jax_log_compiles
        jax.config.update("jax_log_compiles", True)
        self._level = self._logger.level
        self._propagate = self._logger.propagate
        self._logger.setLevel(logging.INFO)
        self._logger.propagate = False
        # park the logger's own handlers (jax installs a stderr
        # StreamHandler) so compile records feed the listener, not stderr;
        # other CompileListeners stay attached so nested watches both count
        self._parked = [h for h in self._logger.handlers
                        if not isinstance(h, CompileListener)]
        for h in self._parked:
            self._logger.removeHandler(h)
        self._logger.addHandler(self.listener)
        return self

    def __exit__(self, *exc) -> bool:
        if not self._enabled:
            return False
        import jax
        self._logger.removeHandler(self.listener)
        for h in self._parked:
            self._logger.addHandler(h)
        self._logger.setLevel(self._level)
        self._logger.propagate = self._propagate
        jax.config.update("jax_log_compiles", self._flag)
        return False
