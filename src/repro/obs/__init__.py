"""Observability bundle: span tracing + metrics registry + numeric telemetry.

One ``Obs`` object threads all three pillars (DESIGN.md §15) through an
engine:

  tracer   — host-side span tracer emitting Chrome trace-event JSON
             (``repro.obs.trace``; load the file in Perfetto / chrome://tracing)
  metrics  — typed counters / gauges / streaming-percentile histograms
             (``repro.obs.metrics``); the scheduler's legacy ``stats`` dict
             is a read-only view over this registry
  numerics — hybrid-format telemetry accumulator (``repro.obs.numerics``):
             softmax-input exponent range, fp2fx8 scale histograms, int8
             saturation, convert volume — fed per burst when
             ``ServeConfig.telemetry`` is on
  profile  — per-executable cost book (``repro.obs.profile``, DESIGN.md
             §16): FLOPs/bytes captured at compile time, joined with
             measured dispatch wall-times into achieved GFLOP/s / GB/s /
             roofline-fraction gauges and trace counter tracks.  Capture
             is gated on ``profile.enabled`` (on for the ``--trace``
             bundle) so plain engines never pay the extra re-trace.

Every ``SlotPoolEngine`` owns an Obs (a fresh disabled-tracer one by
default, so two engines never share counters unless the caller passes a
shared bundle on purpose).  ``metrics_path`` + ``snapshot_every_s`` turn on
periodic JSONL snapshot export from inside the serving loop.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

from repro.obs.metrics import Registry
from repro.obs.numerics import NumericsMonitor
from repro.obs.profile import CostBook
from repro.obs.trace import NULL_TRACER, Tracer, compile_watch  # noqa: F401


@dataclasses.dataclass
class Obs:
    tracer: Tracer = dataclasses.field(
        default_factory=lambda: Tracer(enabled=False))
    metrics: Registry = dataclasses.field(default_factory=Registry)
    numerics: NumericsMonitor = dataclasses.field(
        default_factory=NumericsMonitor)
    profile: CostBook = dataclasses.field(default_factory=CostBook)
    # periodic metrics JSONL export (None = no export); snapshots are
    # appended from the serving loop every ``snapshot_every_s`` seconds and
    # once more at the end of every run
    metrics_path: Optional[str] = None
    snapshot_every_s: float = 1.0
    _last_snapshot: float = dataclasses.field(default=0.0, repr=False)

    def __post_init__(self):
        # the cost book emits through THIS bundle's registry/tracer
        self.profile.bind(self.metrics, self.tracer)

    @classmethod
    def enabled(cls, metrics_path: Optional[str] = None,
                snapshot_every_s: float = 1.0) -> "Obs":
        """An Obs with the tracer + cost profiling ON (the ``--trace``
        bundle)."""
        return cls(tracer=Tracer(enabled=True),
                   profile=CostBook(enabled=True),
                   metrics_path=metrics_path,
                   snapshot_every_s=snapshot_every_s)

    def maybe_snapshot(self, force: bool = False) -> None:
        """Append a metrics snapshot line to ``metrics_path`` if the export
        cadence (or ``force``) says so.  No-op without a path."""
        if self.metrics_path is None:
            return
        now = time.monotonic()
        if force or now - self._last_snapshot >= self.snapshot_every_s:
            self._last_snapshot = now
            self.metrics.write_jsonl(self.metrics_path)
