"""Device-side performance accounting (DESIGN.md §16).

Three layers on top of the §15 spans/metrics substrate:

  ``exec_cost``  — lower a jitted callable at concrete args and read XLA's
                   HLO cost analysis (FLOPs, bytes accessed,
                   transcendentals).  Lowering only re-traces — it never
                   triggers a second backend compile — so capture at
                   prewarm/build time costs a fraction of the compile the
                   executable is paying anyway.
  ``CostBook``   — the per-executable cost ledger the serving engine feeds:
                   costs recorded at compile time (the same prewarm that
                   runs under ``compile_watch``), wall times observed per
                   dispatch.  The join emits achieved GFLOP/s, GB/s, and
                   the roofline fraction — measured wall time vs the
                   TPU-v5e roofline bound from ``roofline/analysis.py`` +
                   ``roofline/hw.py`` — into the metrics registry
                   (``perf.*{executable=...}``) and as trace counter
                   events on the Perfetto timeline.
  ``microbench`` — registry-driven kernel timing over the same
                   ``analysis/pallas_check.default_registry()`` the tile
                   prover walks: us/call and achieved-vs-peak per
                   (kernel, shape, format), the BENCH_kernels.json rows.

XLA's HLO cost analysis counts a ``while``/``scan`` body ONCE regardless of
trip count (the dry-run path corrects the same way), so ``record`` takes a
``trip_factor`` — callers pass the statically-known scan trip product
(burst steps x layer scan), reusing ``analysis.scan_trip_factor`` policy.

The roofline fraction here is *measured-vs-bound*: bound_s =
max(flops/peak_flops, bytes/hbm_bw) on the TPU-v5e lowering target, over
the measured wall.  On this CPU container (Pallas interpret mode) the
fractions are tiny — that is the point: the artifact stops interpreter
numbers masquerading as hardware results and gives TPU runs a trajectory
to land on.

``xla_profile`` is the programmatic ``jax.profiler`` capture window
(``--xla-profile``): xplane + trace.json.gz artifacts per bench run.
"""
from __future__ import annotations

import contextlib
import time
from typing import Dict, Iterator, List, Optional

import jax

from repro.roofline import analysis, hw


def exec_cost(fn, *args) -> Optional[dict]:
    """FLOPs / bytes / transcendentals of ``fn`` at ``args`` from XLA's HLO
    cost analysis, via ``jit(fn).lower(*args).cost_analysis()``.  Returns
    None when the backend offers no analysis (never raises) — callers must
    treat cost rows as best-effort."""
    try:
        cost = fn.lower(*args).cost_analysis()
    except Exception:
        return None
    if isinstance(cost, (list, tuple)):  # some jax versions: per-device list
        cost = cost[0] if cost else None
    if not isinstance(cost, dict):
        return None
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "transcendentals": float(cost.get("transcendentals", 0.0))}


def join_cost(cost: dict, wall_s: float) -> dict:
    """Join a cost row with a measured wall time: achieved GFLOP/s and
    GB/s, the TPU-v5e roofline bound (via ``analysis.analyze`` so the
    compute/memory terms and the dominant-term logic are the dry-run's),
    and the fraction of that bound the measured time achieves."""
    roof = analysis.analyze(
        {"flops": cost["flops"], "bytes accessed": cost["bytes"]},
        hlo_text="", chips=1)
    bound_s = roof.step_time_s
    return {
        "achieved_gflops": cost["flops"] / wall_s / 1e9,
        "achieved_gbps": cost["bytes"] / wall_s / 1e9,
        "peak_gflops": hw.PEAK_FLOPS_BF16 / 1e9,
        "peak_gbps": hw.HBM_BW / 1e9,
        "bound_us": bound_s * 1e6,
        "roofline_fraction": bound_s / wall_s if wall_s > 0 else 0.0,
        "bound_dominant": roof.dominant,
    }


class CostBook:
    """Per-executable cost ledger + wall-time join (DESIGN.md §16).

    ``record`` runs at compile time (prewarm / executable build) and is
    gated on ``enabled`` so engines built by tests and production paths
    never pay the extra re-trace; ``observe`` runs on the hot path and is
    one dict probe when nothing was recorded.  ``bind`` attaches the Obs
    bundle's registry + tracer so joins land as ``perf.*`` gauges and
    ``roofline.*`` counter tracks.
    """

    def __init__(self, enabled: bool = False):
        self.enabled = enabled
        self.costs: Dict[str, dict] = {}
        self._agg: Dict[str, dict] = {}
        self._metrics = None
        self._tracer = None

    def bind(self, metrics, tracer) -> None:
        self._metrics = metrics
        self._tracer = tracer

    def __contains__(self, name: str) -> bool:
        return name in self.costs

    def record(self, name: str, fn, *args, trip_factor: float = 1.0
               ) -> Optional[dict]:
        """Capture ``fn``'s cost at ``args`` under ``name``.  Idempotent
        per name (an executable's cost is static), no-op unless enabled."""
        if not self.enabled:
            return None
        if name in self.costs:
            return self.costs[name]
        c = exec_cost(fn, *args)
        if c is None:
            return None
        c = {"flops": c["flops"] * trip_factor,
             "bytes": c["bytes"] * trip_factor,
             "transcendentals": c["transcendentals"] * trip_factor,
             "trip_factor": trip_factor}
        self.costs[name] = c
        return c

    def observe(self, name: str, wall_s: float) -> Optional[dict]:
        """Join one measured dispatch of ``name`` against its recorded
        cost; emits gauges/histogram/counter-track and returns the join
        (None when no cost is on record — the disabled-path cost is this
        one dict probe)."""
        cost = self.costs.get(name)
        if cost is None or wall_s <= 0:
            return None
        j = join_cost(cost, wall_s)
        agg = self._agg.setdefault(name, {"calls": 0, "wall_s": 0.0})
        agg["calls"] += 1
        agg["wall_s"] += wall_s
        if self._metrics is not None:
            lab = dict(executable=name)
            self._metrics.gauge("perf.achieved_gflops", **lab).set(
                j["achieved_gflops"])
            self._metrics.gauge("perf.achieved_gbps", **lab).set(
                j["achieved_gbps"])
            self._metrics.gauge("perf.roofline_fraction", **lab).set(
                j["roofline_fraction"])
            self._metrics.histogram("perf.wall_s", **lab).observe(wall_s)
        if self._tracer is not None and self._tracer.enabled:
            self._tracer.counter(
                f"roofline.{name}", cat="perf",
                gflops=j["achieved_gflops"], gbps=j["achieved_gbps"],
                frac=j["roofline_fraction"])
        return j

    def summary(self) -> Dict[str, dict]:
        """Per-executable rows: static cost + the join at the mean
        observed wall time (executables recorded but never dispatched
        carry the cost alone)."""
        rows: Dict[str, dict] = {}
        for name, cost in sorted(self.costs.items()):
            row = dict(cost)
            agg = self._agg.get(name)
            if agg and agg["calls"]:
                mean = agg["wall_s"] / agg["calls"]
                row.update(calls=agg["calls"], wall_mean_us=mean * 1e6,
                           **join_cost(cost, mean))
            rows[name] = row
        return rows


@contextlib.contextmanager
def xla_profile(outdir: Optional[str]) -> Iterator[None]:
    """Programmatic ``jax.profiler`` capture window: xplane + trace
    artifacts land under ``outdir`` (no-op when ``outdir`` is falsy, so
    call sites thread the ``--xla-profile`` flag through unconditionally).
    """
    if not outdir:
        yield
        return
    jax.profiler.start_trace(outdir)
    try:
        yield
    finally:
        jax.profiler.stop_trace()


def _block(x) -> None:
    jax.block_until_ready(x)


def microbench(entries=None, iters: int = 5, report=None) -> List[dict]:
    """Time every kernel in the registry (jitted, steady-state) and join
    against its HLO cost: one row per (kernel, shape, format) with
    us/call, GFLOP/s, GB/s, and the roofline fraction vs the TPU-v5e
    bound.  ``entries`` defaults to the same 10-kernel
    ``pallas_check.default_registry()`` the tile prover covers, so bench
    coverage and bounds coverage cannot drift apart."""
    from repro.analysis.pallas_check import default_registry
    rows: List[dict] = []
    for entry in entries if entries is not None else default_registry():
        fn, args = entry.make()
        jfn = jax.jit(fn)
        cost = exec_cost(jfn, *args)
        _block(jfn(*args))  # compile  # lint: allow(obs.untimed-hot-path)
        t0 = time.perf_counter()
        for _ in range(iters):
            out = jfn(*args)  # lint: allow(obs.untimed-hot-path)
        _block(out)
        us = (time.perf_counter() - t0) / iters * 1e6
        fmt = entry.name.partition("[")[2].rstrip("]") or "float32"
        row = {"kernel": entry.name, "format": fmt,
               "shapes": ["x".join(map(str, a.shape)) for a in args],
               "dtypes": [str(a.dtype) for a in args],
               "iters": iters, "us_per_call": us}
        if cost is not None:
            row.update(cost)
            row.update(join_cost(cost, us * 1e-6))
        rows.append(row)
        if report is not None:
            frac = row.get("roofline_fraction")
            report(f"bench_kernels,{entry.name},us_per_call={us:.1f},"
                   f"gflops={row.get('achieved_gflops', 0):.3f},"
                   f"gbps={row.get('achieved_gbps', 0):.3f},"
                   f"bound_us={row.get('bound_us', 0):.3f},"
                   f"frac={frac if frac is None else format(frac, '.2e')}")
    return rows
