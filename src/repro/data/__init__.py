from repro.data.synthetic import DataConfig, classify_batch, lm_batch  # noqa: F401
