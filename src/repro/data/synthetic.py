"""Deterministic synthetic data pipeline (host-shardable, restart-safe).

Real clusters read sharded files; offline we generate *deterministic*
batches keyed by (seed, step, host_shard) so that (a) a restarted job
resumes mid-epoch bit-identically, (b) each data-parallel host generates
only its own shard — no cross-host I/O, and (c) elasticity (a changed host
count) re-partitions the same global stream.

Two generators:
  lm_batch        — order-2 Markov token stream (learnable structure so the
                    100M example demonstrably trains).
  classify_batch  — Gaussian-cluster classification (Table 1/2 proxy task).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

I32 = jnp.int32
F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0


def _fold(seed: int, *vals: int):
    key = jax.random.PRNGKey(seed)
    for v in vals:
        key = jax.random.fold_in(key, v)
    return key


def lm_batch(cfg: DataConfig, step: int) -> dict:
    """Markov-chain tokens: next ~ f(prev, prev2) through a fixed random
    transition mix. Local shard of the global batch."""
    per_host = cfg.global_batch // cfg.n_hosts
    key = _fold(cfg.seed, step, cfg.host_id)
    k1, k2, k3 = jax.random.split(key, 3)
    # fixed transition structure derived from the seed only
    tkey = jax.random.PRNGKey(cfg.seed + 7919)
    shift1 = jax.random.randint(tkey, (cfg.vocab,), 0, cfg.vocab, I32)
    noise = jax.random.bernoulli(k2, 0.15, (per_host, cfg.seq_len + 1))
    rand_tok = jax.random.randint(k3, (per_host, cfg.seq_len + 1), 0,
                                  cfg.vocab, I32)

    def step_fn(carry, xs):
        nz, rt = xs
        nxt = jnp.where(nz, rt, (shift1[carry] + carry) % cfg.vocab)
        return nxt, nxt

    t0 = jax.random.randint(k1, (per_host,), 0, cfg.vocab, I32)
    _, toks = jax.lax.scan(step_fn, t0, (noise.T, rand_tok.T))
    toks = jnp.concatenate([t0[None], toks], 0).T  # (B, S+2)? -> slice
    tokens = toks[:, : cfg.seq_len]
    targets = toks[:, 1: cfg.seq_len + 1]
    return {"tokens": tokens, "targets": targets,
            "mask": jnp.ones_like(targets, F32)}


def classify_batch(seed: int, step: int, batch: int, seq: int, vocab: int,
                   n_classes: int = 4) -> dict:
    """Token sequences whose class is determined by which of ``n_classes``
    marker tokens dominates — linearly separable given attention pooling."""
    key = _fold(seed, step)
    k1, k2, k3 = jax.random.split(key, 3)
    labels = jax.random.randint(k1, (batch,), 0, n_classes, I32)
    markers = labels[:, None] + 1  # tokens 1..n_classes are markers
    base = jax.random.randint(k2, (batch, seq), n_classes + 1, vocab, I32)
    is_marker = jax.random.bernoulli(k3, 0.3, (batch, seq))
    tokens = jnp.where(is_marker, markers, base)
    return {"tokens": tokens, "labels": labels}
