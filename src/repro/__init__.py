"""repro: Hyft (hybrid-numeric-format softmax) as a multi-pod JAX framework.

Layers: core (the paper's technique), kernels (Pallas TPU), models (10 assigned
architectures), configs, data, optim, checkpoint, distributed, train, serve,
launch (mesh + dry-run + CLIs), roofline.
"""
__version__ = "1.0.0"
