"""TPU v5e hardware constants (the lowering target; container is CPU-only)."""

PEAK_FLOPS_BF16 = 197e12     # per chip, bf16
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (~per-chip effective)

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}
