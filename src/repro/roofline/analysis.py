"""Three-term roofline from the compiled dry-run artifact.

  compute    = global_HLO_FLOPs / (chips * peak)
  memory     = global_HLO_bytes / (chips * hbm_bw)
  collective = per_device_collective_bytes / link_bw

``compiled.cost_analysis()`` reports the *per-device* SPMD program, so
global = per_device * chips.  Collective bytes are not in cost_analysis —
we parse the post-SPMD HLO text and sum the operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

MODEL_FLOPS = 6*N*D (train) or 2*N*D (inference) with N the (active) param
count; the ratio MODEL_FLOPS / global_HLO_FLOPs exposes remat/redundancy
overhead (>1 means the compiled program does *less* than the analytic count
would suggest — e.g. factored attention; <1 means recompute/waste).
"""
from __future__ import annotations

import dataclasses
import re

from repro.roofline import hw

_SHAPE_RE = re.compile(r"\b(f64|f32|f16|bf16|f8e4m3|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")
# `%name = TYPE ...` definition lines (TYPE may be a tuple)
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([A-Za-z0-9_.\-]+)\s*=\s*([^=]*?)\s+"
                     r"([a-z][a-z0-9\-]*)\(")
# collective ops: the op name directly follows the result type
_COLL_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(([^)]*)\)")
_OPERAND_RE = re.compile(r"%([A-Za-z0-9_.\-]+)")


def shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * hw.DTYPE_BYTES[dtype]


def type_bytes(type_str: str) -> int:
    return sum(shape_bytes(d, s) for d, s in _SHAPE_RE.findall(type_str))


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-device bytes moved through each collective kind (operand sizes).

    Post-SPMD HLO text references operands by name only, so we first build a
    name -> result-type-bytes map from every definition line, then sum the
    operand sizes of each all-reduce / all-gather / reduce-scatter /
    all-to-all / collective-permute (async ``-start`` forms included, their
    ``-done`` halves not double-counted).
    """
    defs: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            name, type_str, _ = m.groups()
            defs[name] = type_bytes(type_str)
    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_OP_RE.search(line)
        if not m:
            continue
        kind, operands = m.group(1), m.group(2)
        total = sum(defs.get(nm, 0) for nm in _OPERAND_RE.findall(operands))
        if total == 0:  # fall back to the result type (== operand for AR)
            head = line.split(f" {kind}", 1)[0]
            total = type_bytes(head)
        out[kind] = out.get(kind, 0) + total
    return out


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops_global: float
    hlo_bytes_global: float
    coll_bytes_device: float
    coll_breakdown: dict
    chips: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """No-overlap upper bound (sum) — we also report max() as the
        perfectly-overlapped bound."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """compute_s / step_time_s: 1.0 = pure compute-bound (ideal)."""
        t = self.step_time_s
        return self.compute_s / t if t > 0 else 0.0

    def to_dict(self):
        return {**dataclasses.asdict(self),
                "dominant": self.dominant,
                "step_time_s": self.step_time_s,
                "roofline_fraction": self.roofline_fraction}


def analyze(cost: dict, hlo_text: str, chips: int,
            trip_factor: float = 1.0) -> Roofline:
    """``trip_factor`` corrects XLA's known while-loop undercount: HLO cost
    analysis counts each loop body ONCE regardless of trip count (verified on
    this backend — see EXPERIMENTS.md §Dry-run).  Our models put virtually
    all compute inside ``lax.scan`` (layers x microbatches x token steps), so
    we scale per-device flops/bytes/collectives by the statically-known trip
    product (``scan_trip_factor`` below).  Loop-external work (embeddings,
    loss, optimizer update) gets over-scaled by the same factor — a bounded,
    documented distortion (small vs. L x per-layer cost)."""
    flops_dev = float(cost.get("flops", 0.0)) * trip_factor
    bytes_dev = float(cost.get("bytes accessed", 0.0)) * trip_factor
    coll = collective_bytes(hlo_text)
    coll_dev = float(sum(coll.values())) * trip_factor
    return Roofline(
        compute_s=flops_dev / hw.PEAK_FLOPS_BF16,
        memory_s=bytes_dev / hw.HBM_BW,
        collective_s=coll_dev / hw.ICI_BW,
        hlo_flops_global=flops_dev * chips,
        hlo_bytes_global=bytes_dev * chips,
        coll_bytes_device=coll_dev,
        coll_breakdown=coll,
        chips=chips,
    )


def scan_trip_factor(cfg, shape_kind: str, seq: int, global_batch: int,
                     microbatch: int) -> float:
    """Product of the statically-known trip counts along the dominant path.

    train: layers-scan (fwd body + bwd body both scale with L) x grad-accum
    microbatch trips.  prefill/decode: layers-scan; SSM/hybrid/enc-dec
    prefill additionally scans over tokens.  The SSD inter-chunk state scan
    is flop-negligible (elementwise) and left uncorrected.
    """
    layers = cfg.n_layers + (cfg.enc_layers if shape_kind == "train" else 0)
    if shape_kind == "train":
        mb_trips = (global_batch // microbatch) if microbatch else 1
        return float(max(layers, 1) * max(mb_trips, 1))
    if shape_kind == "prefill":
        sequential = (cfg.family in ("ssm", "hybrid", "encdec")
                      and not cfg.parallel_prefill)
        token_scan = seq if sequential else 1
        return float(max(cfg.n_layers, 1) * token_scan)
    return float(max(cfg.n_layers, 1))  # decode


# ---------------------------------------------------------------------------
# analytic MODEL_FLOPS
# ---------------------------------------------------------------------------


def count_params(cfg, active_only: bool = False) -> int:
    """Analytic parameter count from the config (embedding included once)."""
    dm, dff, V = cfg.d_model, cfg.d_ff, cfg.vocab
    attn = 0
    if cfg.n_heads:
        attn = dm * cfg.n_heads * cfg.d_head + 2 * dm * cfg.n_kv_heads * cfg.d_head \
            + cfg.n_heads * cfg.d_head * dm
    mlp = dm * dff * (3 if cfg.mlp_gated else 2) if dff else 0
    ssm = 0
    if cfg.ssm_state:
        d_inner = cfg.ssm_expand * dm
        H = d_inner // cfg.ssm_head_dim
        proj = dm * (2 * d_inner + 2 * cfg.ssm_state + H)
        ssm = proj + d_inner * dm + cfg.ssm_conv * (d_inner + 2 * cfg.ssm_state)
    emb = V * dm * (1 if cfg.tie_embeddings else 2)

    if cfg.family in ("dense", "vlm"):
        core = cfg.n_layers * (attn + mlp)
    elif cfg.family == "moe":
        e = cfg.moe_top_k if active_only else cfg.n_experts
        core = cfg.n_layers * (attn + mlp * e + dm * cfg.n_experts)
    elif cfg.family == "ssm":
        core = cfg.n_layers * ssm
    elif cfg.family == "hybrid":
        n_attn_calls = cfg.n_layers // cfg.attn_every
        shared = attn + mlp  # one shared block
        core = cfg.n_layers * ssm + (shared if not active_only
                                     else shared)  # params counted once
        if active_only:
            core = cfg.n_layers * ssm + n_attn_calls * (attn + mlp)
    elif cfg.family == "encdec":
        core = cfg.n_layers * (2 * attn + mlp) + cfg.enc_layers * (attn + mlp)
    else:
        core = 0
    return core + emb


def model_flops(cfg, tokens: int, kind: str) -> float:
    """6*N*D for train, 2*N*D for inference (active params for MoE)."""
    n = count_params(cfg, active_only=True)
    return (6.0 if kind == "train" else 2.0) * n * tokens
