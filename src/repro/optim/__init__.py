from repro.optim.adamw import (  # noqa: F401
    OptConfig,
    clip_by_global_norm,
    global_norm,
    init,
    update,
)
from repro.optim.schedules import SCHEDULES, warmup_cosine  # noqa: F401
