"""LR schedules (as scale factors applied to the base lr)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, warmup: int, total: int, final_frac: float = 0.1):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return warm * cos


def constant(step, **_):
    return jnp.ones_like(step, jnp.float32)


SCHEDULES = {"warmup_cosine": warmup_cosine, "constant": constant}
