"""Optimizers: AdamW (fp32 master weights), SGD-momentum, Adafactor.

Mixed precision: model params may be bf16; the optimizer keeps fp32 master
copies and re-casts after the update (standard large-model practice).
Adafactor factors the second moment of >=2-D params (row+col statistics) —
the memory-roofline lever for the 340B/314B archs.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.1
    momentum: float = 0.9
    master_dtype: str = "float32"


def init(cfg: OptConfig, params) -> dict[str, Any]:
    def master(p):
        # force a distinct buffer even when dtypes match: params and master
        # are donated separately by the train step (aliasing would trip
        # XLA's double-donation check)
        return jnp.array(p, dtype=cfg.master_dtype, copy=True)

    if cfg.name == "sgd":
        return {"step": jnp.zeros((), jnp.int32),
                "master": jax.tree.map(master, params),
                "mom": jax.tree.map(lambda p: jnp.zeros_like(p, F32), params)}
    if cfg.name == "adafactor":
        def vrow(p):
            return (jnp.zeros(p.shape[:-1], F32) if p.ndim >= 2
                    else jnp.zeros_like(p, F32))

        def vcol(p):
            return (jnp.zeros(p.shape[:-2] + p.shape[-1:], F32)
                    if p.ndim >= 2 else jnp.zeros((0,), F32))
        return {"step": jnp.zeros((), jnp.int32),
                "master": jax.tree.map(master, params),
                "vr": jax.tree.map(vrow, params),
                "vc": jax.tree.map(vcol, params)}
    # adamw
    return {"step": jnp.zeros((), jnp.int32),
            "master": jax.tree.map(master, params),
            "m": jax.tree.map(lambda p: jnp.zeros_like(p, F32), params),
            "v": jax.tree.map(lambda p: jnp.zeros_like(p, F32), params)}


def update(cfg: OptConfig, grads, opt_state, params, lr_scale=1.0):
    """Returns (new_params, new_opt_state)."""
    step = opt_state["step"] + 1
    lr = cfg.lr * lr_scale

    if cfg.name == "sgd":
        def upd(g, mom, mst):
            g = g.astype(F32)
            mom = cfg.momentum * mom + g
            mst = mst - lr * (mom + cfg.weight_decay * mst.astype(F32)).astype(mst.dtype)
            return mst, mom
        out = jax.tree.map(upd, grads, opt_state["mom"], opt_state["master"])
        masters = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        moms = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_params = jax.tree.map(lambda m, p: m.astype(p.dtype), masters, params)
        return new_params, {"step": step, "master": masters, "mom": moms}

    if cfg.name == "adafactor":
        def upd(g, vr, vc, mst):
            g32 = g.astype(F32)
            if g32.ndim >= 2:
                vr = cfg.b2 * vr + (1 - cfg.b2) * jnp.mean(g32 * g32, axis=-1)
                vc = cfg.b2 * vc + (1 - cfg.b2) * jnp.mean(g32 * g32, axis=-2)
                r = vr[..., None] / jnp.maximum(
                    jnp.mean(vr, axis=-1, keepdims=True), 1e-30)[..., None]
                denom = jnp.sqrt(r * vc[..., None, :]) + cfg.eps
            else:
                vr = cfg.b2 * vr + (1 - cfg.b2) * g32 * g32
                denom = jnp.sqrt(vr) + cfg.eps
            upd_ = g32 / denom + cfg.weight_decay * mst.astype(F32)
            mst = (mst.astype(F32) - lr * upd_).astype(mst.dtype)
            return mst, vr, vc
        triples = jax.tree.map(upd, grads, opt_state["vr"], opt_state["vc"],
                               opt_state["master"])
        is3 = lambda x: isinstance(x, tuple)
        masters = jax.tree.map(lambda t: t[0], triples, is_leaf=is3)
        vrs = jax.tree.map(lambda t: t[1], triples, is_leaf=is3)
        vcs = jax.tree.map(lambda t: t[2], triples, is_leaf=is3)
        new_params = jax.tree.map(lambda m, p: m.astype(p.dtype), masters, params)
        return new_params, {"step": step, "master": masters, "vr": vrs, "vc": vcs}

    # adamw
    bc1 = 1 - cfg.b1 ** step.astype(F32)
    bc2 = 1 - cfg.b2 ** step.astype(F32)

    def upd(g, m, v, mst):
        g32 = g.astype(F32)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * g32 * g32
        mhat, vhat = m / bc1, v / bc2
        step_ = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * mst.astype(F32)
        mst = (mst.astype(F32) - lr * step_).astype(mst.dtype)
        return mst, m, v
    triples = jax.tree.map(upd, grads, opt_state["m"], opt_state["v"],
                           opt_state["master"])
    is3 = lambda x: isinstance(x, tuple)
    masters = jax.tree.map(lambda t: t[0], triples, is_leaf=is3)
    ms = jax.tree.map(lambda t: t[1], triples, is_leaf=is3)
    vs = jax.tree.map(lambda t: t[2], triples, is_leaf=is3)
    new_params = jax.tree.map(lambda m, p: m.astype(p.dtype), masters, params)
    return new_params, {"step": step, "master": masters, "m": ms, "v": vs}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(x.astype(F32) ** 2)
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    gn = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda x: (x.astype(F32) * scale).astype(x.dtype), tree), gn
