"""Gradient compression: int8 stochastic-rounding collective payloads.

On a 1000+-node fleet the DP gradient all-reduce is the dominant cross-pod
collective; compressing payloads to int8 cuts the collective-roofline term
~4x (fp32) / ~2x (bf16).  We quantize per-tensor with a shared scale,
stochastic rounding keeps the expectation unbiased, and the psum happens on
int32 accumulators (no overflow for <= 2^23 participants at int8).

Used inside ``shard_map``-based DP reductions (``compressed_psum_tree``) and
unit-tested for unbiasedness in ``tests/test_compression.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def quantize_int8(x: jax.Array, key) -> tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8 with stochastic rounding. Returns (q, scale)."""
    x32 = x.astype(F32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    y = x32 / scale
    noise = jax.random.uniform(key, x.shape, F32)
    q = jnp.floor(y + noise)
    return jnp.clip(q, -128, 127).astype(jnp.int8), scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(F32) * scale


def compressed_psum_tree(tree, axis_name: str, key):
    """Quantize -> psum(int32) -> dequant, per leaf.  The scale itself is
    pmax'd so every participant uses a common grid (required for exactness of
    the integer sum)."""
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    out = []
    for x, k in zip(leaves, keys):
        x32 = x.astype(F32)
        scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
        scale = jax.lax.pmax(scale, axis_name)
        noise = jax.random.uniform(k, x.shape, F32)
        q = jnp.clip(jnp.floor(x32 / scale + noise), -128, 127).astype(jnp.int32)
        s = jax.lax.psum(q, axis_name)
        out.append((s.astype(F32) * scale).astype(x.dtype))
    return jax.tree.unflatten(treedef, out)
