"""Fused flash attention with Hyft softmax — the TPU-native form of §3.6.

The paper pipelines softmax's three stages (max | exp+sum | div) *across
vectors* because one vector's stages are sequential.  On TPU the same row
independence is exploited the opposite way: we stream KV blocks through VMEM
and maintain *online* (max, sum, acc) state per query row, so stage 1/2/3 of
consecutive blocks overlap inside one kernel — one HBM pass over K/V instead
of the three passes an unfused QK^T -> softmax -> PV takes.  The paper's
L1/L2 tree of Hyft units (Fig. 6) is exactly the associative (max,sum) merge
used here blockwise (and cross-device in ``repro.models.attention``'s
sequence-parallel decode).

All softmax arithmetic inside is Hyft's: FP2FX, Booth shift-add, field
assembly, fixed-point accumulation, and the final log-subtract division.
The online rescale multiplies by the *Hyft-approximated* exp of the max
delta (the DIV/MUL unit in rescale duty).

Forward accumulator pattern: (bh, q, kv) grid with kv innermost; output
blocks and the (m, l) stat blocks map to the same index for every kv step,
so they stay resident in VMEM and serve as carry; finalization happens at
the last step.

Mask contract (DESIGN.md §3): ``kv_len_mask`` is an optional float32
``(B, Sk)`` array, 1.0 = valid KV position, 0.0 = padded/invalid.  Masking
happens on the *float scores before FP2FX* (identical to the unfused path):
invalid scores become ``NEG_BIG``, the converter saturates them to the
fixed-point minimum and the exponent unit flushes their probability to zero.
Sequences that are not block multiples are padded automatically and the
padding is folded into the same mask.

Backward (paper §3.5, training mode): a ``jax.custom_vjp`` whose bwd is two
Pallas kernels that *recompute* the Hyft probabilities per (q, kv) block
from the saved final row stats ``(m, l)`` — flash-style, single pass, no
online rescale — mirroring the arithmetic of ``_cha_bwd`` in
``repro.models.attention``:

  p  = log_div(exp_unit(fp2fx(z) - m), lod_refloat(l))   # DIV unit reused
  dv = p^T do;  dp = do v^T;  ds = p (dp - delta);  delta = <do, o>
  dq = ds k * scale;  dk = ds^T q * scale

The dq kernel runs on a (bh, q, kv) grid with the dq block as carry over kv
steps; the dk/dv kernel runs on a (bh_kv, kv, group*q) grid with the dk/dv
blocks as carry over the fused (GQA group x q block) inner dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import numerics as nm
from repro.core.hyft import HyftConfig

F32 = jnp.float32
I32 = jnp.int32
NEG_BIG = -3.0e38  # pre-quantization mask value; FP2FX saturates it to fx lo


def _pad0(x, widths):
    """``jnp.pad`` with a dtype-matched zero fill: the default Python-int
    fill is a weak scalar that inserts a convert_element_type per pad (int8
    KV raws included), which the format-flow auditor counts as churn."""
    return jnp.pad(x, widths, constant_values=x.dtype.type(0))


def hyft_finalize(acc, l, cfg: HyftConfig):
    """Hyft stage 3: log-subtract division ``acc / l`` through the DIV unit.

    acc: (..., D) fp32 PV accumulator; l: (..., 1) fp32 fixed-point sum.
    Shared by the fused kernels' last step, the chunked path, the
    sequence-parallel combine, and the split-K decode combine — one
    arithmetic, so every online mode finalizes identically.
    """
    e_b, m_b = nm.lod_refloat(l, cfg.mant_bits)
    sg, e_n, m_n = nm.float_fields(acc, cfg.mant_bits)
    res = nm.log_div(e_n, m_n, e_b, m_b, cfg.mant_bits)
    res = jnp.where(sg == 1, -res, res)
    return jnp.where(acc == F32(0), F32(0), res)


def hyft_alpha(d_raw, cfg: HyftConfig):
    """Hyft-approximated ``exp(d)`` of a fixed-point max delta (d <= 0),
    assembled to fp32 — the DIV/MUL unit in rescale duty (online merges)."""
    e_a, m_a = nm.exp_unit(d_raw, cfg.frac_bits, cfg.mant_bits)
    return ((1 << cfg.mant_bits) + m_a).astype(F32) * nm.pow2_float(
        e_a - cfg.mant_bits)


# --------------------------------------------------------------------------
# forward kernel
# --------------------------------------------------------------------------


def _flash_fwd_kernel(*refs, cfg: HyftConfig, sm_scale: float, causal: bool,
                      block_q: int, block_k: int, nk: int, q_offset: int,
                      has_mask: bool):
    if has_mask:
        q_ref, k_ref, v_ref, mask_ref, o_ref, m_ref, l_ref = refs
    else:
        q_ref, k_ref, v_ref, o_ref, m_ref, l_ref = refs
        mask_ref = None
    iq, ik = pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, -(2 ** (cfg.total_bits - 1)))
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(F32)              # (bq, dh)
    k = k_ref[0].astype(F32)              # (bk, dh)
    v = v_ref[0].astype(F32)              # (bk, dh)
    z = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=F32) * sm_scale
    if causal:
        qi = q_offset + iq * block_q + jax.lax.broadcasted_iota(I32, z.shape, 0)
        ki = ik * block_k + jax.lax.broadcasted_iota(I32, z.shape, 1)
        z = jnp.where(qi >= ki, z, NEG_BIG)
    if has_mask:  # pre-FP2FX, same as the unfused path
        z = jnp.where(mask_ref[0][None, :] > F32(0), z, NEG_BIG)

    # ---- Hyft stage 1: FP2FX + (strided) block max, merged with running max
    z_raw = nm.fp2fx(z, cfg.frac_bits, cfg.total_bits)
    zsub = z_raw[:, :: cfg.step] if cfg.step > 1 else z_raw
    blk_max = jnp.max(zsub, axis=-1, keepdims=True)
    m_old = m_ref[:, :1]
    m_new = jnp.maximum(m_old, blk_max)

    # ---- Hyft stage 2: exponent unit + fixed-point accumulation
    e, m = nm.exp_unit(z_raw - m_new, cfg.frac_bits, cfg.mant_bits)
    addend = nm.expfloat_to_fx(e, m, cfg.mant_bits, cfg.acc_bits)
    l_blk = jnp.sum(addend, axis=-1, keepdims=True)

    # online rescale of the carried sum/acc by the *Hyft* exp of the max delta
    alpha = hyft_alpha(m_old - m_new, cfg)
    l_new = nm.fx_quantize(l_ref[:, :1] * alpha, cfg.acc_bits) + l_blk

    # ---- probabilities as assembled floats -> MXU matmul with V
    p = ((1 << cfg.mant_bits) + m).astype(F32) * nm.pow2_float(e - cfg.mant_bits)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=F32)
    acc = o_ref[0].astype(F32) * alpha + pv

    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)
    o_ref[...] = acc[None].astype(o_ref.dtype)

    # ---- Hyft stage 3: log-subtract division at the last kv step
    @pl.when(ik == nk - 1)
    def _finalize():
        res = hyft_finalize(o_ref[0].astype(F32), l_ref[:, :1], cfg)
        o_ref[...] = res[None].astype(o_ref.dtype)


def _flash_fwd_impl(q3, k3, v3, maskf, *, cfg: HyftConfig, sm_scale: float,
                    causal: bool, bq: int, bk: int, group: int,
                    q_offset: int, interpret: bool):
    """Blocked forward on pre-padded 3D operands.

    q3: (BH, Sq, D); k3/v3: (BHkv, Sk, D); maskf: (B, Sk) float or None.
    Returns (o (BH,Sq,D) f32, m (BH,Sq) i32 raw, l (BH,Sq) f32).
    """
    BH, Sq, D = q3.shape
    Sk = k3.shape[1]
    Hq_per_b = BH // max(maskf.shape[0], 1) if maskf is not None else 0
    nq, nk = Sq // bq, Sk // bk
    grid = (BH, nq, nk)
    has_mask = maskf is not None

    kern = functools.partial(_flash_fwd_kernel, cfg=cfg, sm_scale=sm_scale,
                             causal=causal, block_q=bq, block_k=bk, nk=nk,
                             q_offset=q_offset, has_mask=has_mask)
    in_specs = [
        pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        pl.BlockSpec((1, bk, D), lambda b, i, j, g=group: (b // g, j, 0)),
        pl.BlockSpec((1, bk, D), lambda b, i, j, g=group: (b // g, j, 0)),
    ]
    operands = [q3, k3, v3]
    if has_mask:
        in_specs.append(
            pl.BlockSpec((1, bk), lambda b, i, j, h=Hq_per_b: (b // h, j)))
        operands.append(maskf)
    o, m_st, l_st = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((bq, 128), lambda b, i, j, n=nq: (b * n + i, 0)),
            pl.BlockSpec((bq, 128), lambda b, i, j, n=nq: (b * n + i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Sq, D), F32),
            jax.ShapeDtypeStruct((BH * Sq, 128), I32),
            jax.ShapeDtypeStruct((BH * Sq, 128), F32),
        ],
        interpret=interpret,
    )(*operands)
    return o, m_st[:, 0].reshape(BH, Sq), l_st[:, 0].reshape(BH, Sq)


# --------------------------------------------------------------------------
# backward kernels (recompute-from-stats, flash-style)
# --------------------------------------------------------------------------


def _recompute_probs(q, k, mask_row, m_row, l_row, *, cfg, sm_scale, causal,
                     qi0, ki0):
    """Hyft probabilities of one (bq, bk) tile from the saved final row stats.

    Identical arithmetic to the chunked path's ``probs``: elementwise, so the
    result is independent of how the forward blocked the KV axis."""
    z = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=F32) * sm_scale
    if causal:
        qi = qi0 + jax.lax.broadcasted_iota(I32, z.shape, 0)
        ki = ki0 + jax.lax.broadcasted_iota(I32, z.shape, 1)
        z = jnp.where(qi >= ki, z, NEG_BIG)
    if mask_row is not None:
        z = jnp.where(mask_row[None, :] > F32(0), z, NEG_BIG)
    z_raw = nm.fp2fx(z, cfg.frac_bits, cfg.total_bits)
    e, m = nm.exp_unit(z_raw - m_row, cfg.frac_bits, cfg.mant_bits)
    e_b, m_b = nm.lod_refloat(l_row, cfg.mant_bits)
    return nm.log_div(e, m, e_b, m_b, cfg.mant_bits)


def _flash_bwd_dq_kernel(*refs, cfg: HyftConfig, sm_scale: float,
                         causal: bool, block_q: int, block_k: int,
                         q_offset: int, has_mask: bool):
    if has_mask:
        (q_ref, k_ref, v_ref, do_ref, delta_ref, m_ref, l_ref, mask_ref,
         dq_ref) = refs
    else:
        q_ref, k_ref, v_ref, do_ref, delta_ref, m_ref, l_ref, dq_ref = refs
        mask_ref = None
    iq, ik = pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        dq_ref[...] = jnp.zeros_like(dq_ref)

    q = q_ref[0].astype(F32)
    k = k_ref[0].astype(F32)
    v = v_ref[0].astype(F32)
    do = do_ref[0].astype(F32)
    p = _recompute_probs(
        q, k, mask_ref[0] if has_mask else None,
        m_ref[0][:, None], l_ref[0][:, None], cfg=cfg, sm_scale=sm_scale,
        causal=causal, qi0=q_offset + iq * block_q, ki0=ik * block_k)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=F32)
    ds = p * (dp - delta_ref[0][:, None])
    dq = jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())),
                             preferred_element_type=F32) * sm_scale
    dq_ref[...] = dq_ref[...] + dq[None]


def _flash_bwd_dkv_kernel(*refs, cfg: HyftConfig, sm_scale: float,
                          causal: bool, block_q: int, block_k: int,
                          nq: int, q_offset: int, has_mask: bool):
    if has_mask:
        (q_ref, do_ref, delta_ref, m_ref, l_ref, k_ref, v_ref, mask_ref,
         dk_ref, dv_ref) = refs
    else:
        (q_ref, do_ref, delta_ref, m_ref, l_ref, k_ref, v_ref,
         dk_ref, dv_ref) = refs
        mask_ref = None
    ik, it = pl.program_id(1), pl.program_id(2)
    iq = it % nq  # q-block index inside the fused (group x q-block) axis

    @pl.when(it == 0)
    def _init():
        dk_ref[...] = jnp.zeros_like(dk_ref)
        dv_ref[...] = jnp.zeros_like(dv_ref)

    q = q_ref[0].astype(F32)
    k = k_ref[0].astype(F32)
    v = v_ref[0].astype(F32)
    do = do_ref[0].astype(F32)
    p = _recompute_probs(
        q, k, mask_ref[0] if has_mask else None,
        m_ref[0][:, None], l_ref[0][:, None], cfg=cfg, sm_scale=sm_scale,
        causal=causal, qi0=q_offset + iq * block_q, ki0=ik * block_k)
    dv = jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                             preferred_element_type=F32)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=F32)
    ds = p * (dp - delta_ref[0][:, None])
    dk = jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                             preferred_element_type=F32) * sm_scale
    dk_ref[...] = dk_ref[...] + dk[None]
    dv_ref[...] = dv_ref[...] + dv[None]


def _flash_bwd_impl(q3, k3, v3, maskf, do3, o3, m2, l2, *, cfg, sm_scale,
                    causal, bq, bk, group, q_offset, interpret, batch):
    """Backward on pre-padded 3D operands; returns (dq3, dk3, dv3)."""
    BH, Sq, D = q3.shape
    BHkv, Sk = k3.shape[0], k3.shape[1]
    nq, nk = Sq // bq, Sk // bk
    has_mask = maskf is not None
    hq_per_b = BH // batch
    delta = jnp.sum(do3.astype(F32) * o3.astype(F32), axis=-1)  # (BH, Sq)

    # ---- dq: (bh, q, kv) grid, kv innermost, dq block as carry ------------
    row_spec = pl.BlockSpec((1, bq), lambda b, i, j: (b, i))
    in_specs = [
        pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        pl.BlockSpec((1, bk, D), lambda b, i, j, g=group: (b // g, j, 0)),
        pl.BlockSpec((1, bk, D), lambda b, i, j, g=group: (b // g, j, 0)),
        pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        row_spec, row_spec, row_spec,
    ]
    operands = [q3, k3, v3, do3, delta, m2, l2]
    if has_mask:
        in_specs.append(
            pl.BlockSpec((1, bk), lambda b, i, j, h=hq_per_b: (b // h, j)))
        operands.append(maskf)
    dq = pl.pallas_call(
        functools.partial(_flash_bwd_dq_kernel, cfg=cfg, sm_scale=sm_scale,
                          causal=causal, block_q=bq, block_k=bk,
                          q_offset=q_offset, has_mask=has_mask),
        grid=(BH, nq, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), F32),
        interpret=interpret,
    )(*operands)

    # ---- dk/dv: (bh_kv, kv, group*q) grid, dk/dv blocks as carry ----------
    # the fused inner axis t enumerates (GQA group member, q block); index
    # maps decode it as head = b*group + t // nq, q block = t % nq.
    qrow3 = pl.BlockSpec(
        (1, bq, D), lambda b, j, t, g=group, n=nq: (b * g + t // n, t % n, 0))
    qrow2 = pl.BlockSpec(
        (1, bq), lambda b, j, t, g=group, n=nq: (b * g + t // n, t % n))
    in_specs = [
        qrow3, qrow3, qrow2, qrow2, qrow2,
        pl.BlockSpec((1, bk, D), lambda b, j, t: (b, j, 0)),
        pl.BlockSpec((1, bk, D), lambda b, j, t: (b, j, 0)),
    ]
    operands = [q3, do3, delta, m2, l2, k3, v3]
    hkv_per_b = BHkv // batch
    if has_mask:
        in_specs.append(
            pl.BlockSpec((1, bk), lambda b, j, t, h=hkv_per_b: (b // h, j)))
        operands.append(maskf)
    dk, dv = pl.pallas_call(
        functools.partial(_flash_bwd_dkv_kernel, cfg=cfg, sm_scale=sm_scale,
                          causal=causal, block_q=bq, block_k=bk, nq=nq,
                          q_offset=q_offset, has_mask=has_mask),
        grid=(BHkv, nk, group * nq),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, bk, D), lambda b, j, t: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, j, t: (b, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BHkv, Sk, D), F32),
            jax.ShapeDtypeStruct((BHkv, Sk, D), F32),
        ],
        interpret=interpret,
    )(*operands)
    return dq, dk, dv


# --------------------------------------------------------------------------
# custom VJP plumbing (operates on pre-padded 4D arrays)
# --------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8, 9, 10))
def _flash_attn(q, k, v, maskf, cfg, sm_scale, causal, bq, bk, interpret,
                q_offset):
    o, _, _ = _flash_fwd_impl(
        _h3(q), _h3(k), _h3(v), maskf, cfg=cfg, sm_scale=sm_scale,
        causal=causal, bq=bq, bk=bk, group=q.shape[1] // k.shape[1],
        q_offset=q_offset, interpret=interpret)
    return o.reshape(q.shape)


def _h3(x):
    B, H, S, D = x.shape
    return x.reshape(B * H, S, D)


def _flash_attn_fwd(q, k, v, maskf, cfg, sm_scale, causal, bq, bk, interpret,
                    q_offset):
    o, m2, l2 = _flash_fwd_impl(
        _h3(q), _h3(k), _h3(v), maskf, cfg=cfg, sm_scale=sm_scale,
        causal=causal, bq=bq, bk=bk, group=q.shape[1] // k.shape[1],
        q_offset=q_offset, interpret=interpret)
    return o.reshape(q.shape), (q, k, v, maskf, o, m2, l2)


def _flash_attn_bwd(cfg, sm_scale, causal, bq, bk, interpret, q_offset,
                    res, do):
    q, k, v, maskf, o3, m2, l2 = res
    dq, dk, dv = _flash_bwd_impl(
        _h3(q), _h3(k), _h3(v), maskf, _h3(do.astype(F32)), o3, m2, l2,
        cfg=cfg, sm_scale=sm_scale, causal=causal, bq=bq, bk=bk,
        group=q.shape[1] // k.shape[1], q_offset=q_offset,
        interpret=interpret, batch=q.shape[0])
    dmask = None if maskf is None else jnp.zeros_like(maskf)
    return (dq.reshape(q.shape).astype(q.dtype),
            dk.reshape(k.shape).astype(k.dtype),
            dv.reshape(v.shape).astype(v.dtype), dmask)


_flash_attn.defvjp(_flash_attn_fwd, _flash_attn_bwd)


# --------------------------------------------------------------------------
# public entry point
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=(
    "cfg", "sm_scale", "causal", "block_q", "block_k", "interpret",
    "return_stats", "q_offset"))
def flash_hyft_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                         cfg: HyftConfig, sm_scale: float | None = None,
                         causal: bool = True, block_q: int = 128,
                         block_k: int = 128, interpret: bool = True,
                         return_stats: bool = False,
                         kv_len_mask: jax.Array | None = None,
                         q_offset: int = 0):
    """Fused attention with Hyft softmax — trainable and mask-aware.

    Args:
      q: (B, Hq, Sq, D);  k, v: (B, Hkv, Sk, D) with Hq % Hkv == 0 (GQA).
      kv_len_mask: optional (B, Sk) validity mask (bool or float, nonzero =
        valid) — the decode/serving cache mask.  Applied pre-FP2FX exactly
        like the unfused path.
      q_offset: static int added to query positions for the causal mask
        (partial-prefill continuation).
    Returns (B, Hq, Sq, D) in fp32 (callers cast).  Differentiable: the VJP
    runs the fused Pallas backward kernels (recompute from the saved (m, l)
    row stats through the reused DIV/MUL datapath).  With ``return_stats``
    also returns the (m, l) row stats (forward-only; used by the
    cross-device sequence-parallel combine).
    """
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    assert Hq % Hkv == 0
    scale = sm_scale if sm_scale is not None else D ** -0.5
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    pad_q, pad_k = (-Sq) % bq, (-Sk) % bk
    maskf = None
    if kv_len_mask is not None:
        maskf = kv_len_mask.astype(F32)
    elif pad_k:
        maskf = jnp.ones((B, Sk), F32)
    if pad_q:
        q = _pad0(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = _pad0(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = _pad0(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        maskf = _pad0(maskf, ((0, 0), (0, pad_k)))

    if return_stats:  # forward-only path (sequence-parallel combine)
        o, m2, l2 = _flash_fwd_impl(
            _h3(q), _h3(k), _h3(v), maskf, cfg=cfg, sm_scale=scale,
            causal=causal, bq=bq, bk=bk, group=Hq // Hkv,
            q_offset=q_offset, interpret=interpret)
        o = o.reshape(q.shape)[:, :, :Sq]
        m2 = m2.reshape(B, Hq, -1)[:, :, :Sq]
        l2 = l2.reshape(B, Hq, -1)[:, :, :Sq]
        return o, m2, l2

    out = _flash_attn(q, k, v, maskf, cfg, scale, causal, bq, bk, interpret,
                      q_offset)
    return out[:, :, :Sq]


# --------------------------------------------------------------------------
# split-K decode kernel (Sq = 1)
# --------------------------------------------------------------------------
#
# Decode streams the whole KV cache past a single query row, so the monolithic
# kernel's (bh, q, kv) grid degenerates to one q block of one row.  The decode
# kernel instead (a) folds the GQA group into the tile's row dimension — the
# group's queries share each K/V block load — and (b) splits the KV axis
# across the grid, each split emitting *local* Hyft (max, fixed-sum, acc)
# stats.  The cross-split combine is the paper's L1/L2 tree exactly as
# ``sp_decode_attention`` applies it across devices: integer max over split
# maxima, per-split rescale by the Hyft-approximated exp of the max delta,
# fixed-point sum merge, one ``lod_refloat`` + ``log_div`` finalize.
#
# K/V may arrive FP2FX-quantized (int8 raw + per-(head, position) scale, the
# fp2fx8 KV-cache layout in ``repro.models.attention``); dequantization is
# fused into the kernel's K/V loads so the HBM traffic stays int8.


def _decode_tile(q, k, v, maskrow, cfg: HyftConfig, sm_scale: float):
    """L1 of the decode tree: local Hyft stages 1-2 for one KV split.

    q (gp, dh) — GQA group folded into rows; k/v (bk, dh) fp32 (already
    dequantized); maskrow (bk,) shared across rows, or (gp, bk) per-row
    (the verify kernel's causal-within-draft mask).  Returns (acc (gp, dh),
    m_loc (gp, 1) raw, l_loc (gp, 1)) — the split-local (max, fixed-sum,
    acc) stats.  Shared verbatim by the contiguous split-K kernel, the
    paged kernel, and the verify kernels, so a page IS a split and the
    bitwise story reduces to the combine order.
    """
    z = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=F32) * sm_scale
    mrow = maskrow if maskrow.ndim == 2 else maskrow[None, :]
    z = jnp.where(mrow > F32(0), z, NEG_BIG)
    z_raw = nm.fp2fx(z, cfg.frac_bits, cfg.total_bits)
    zsub = z_raw[:, :: cfg.step] if cfg.step > 1 else z_raw
    m_loc = jnp.max(zsub, axis=-1, keepdims=True)
    e, m = nm.exp_unit(z_raw - m_loc, cfg.frac_bits, cfg.mant_bits)
    addend = nm.expfloat_to_fx(e, m, cfg.mant_bits, cfg.acc_bits)
    l_loc = jnp.sum(addend, axis=-1, keepdims=True)
    p = ((1 << cfg.mant_bits) + m).astype(F32) * nm.pow2_float(e - cfg.mant_bits)
    acc = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                              preferred_element_type=F32)
    return acc, m_loc, l_loc


def _splitk_combine(acc, m_st, l_st, cfg: HyftConfig):
    """L2 of the decode tree: merge per-split Hyft stats across the split
    axis (axis 1) — integer max over split maxima, per-split rescale by the
    Hyft-approximated exp of the max delta, fixed-point sum merge, one
    finalize.  acc (BH, ns, gp, D); m_st (BH, ns, gp, 128) i32; l_st f32.
    Shared by the contiguous and paged decode kernels: identical inputs in
    identical split order give bitwise-identical outputs.
    """
    m_loc = m_st[..., 0]                        # (BH, ns, gp) i32
    l_loc = l_st[..., 0]                        # (BH, ns, gp) f32
    m_glob = jnp.max(m_loc, axis=1, keepdims=True)
    alpha = hyft_alpha(m_loc - m_glob, cfg)     # per-split rescale
    l_glob = jnp.sum(nm.fx_quantize(l_loc * alpha, cfg.acc_bits), axis=1)
    acc_glob = jnp.sum(acc * alpha[..., None], axis=1)   # (BH, gp, D)
    return hyft_finalize(acc_glob, l_glob[..., None], cfg)


def _decode_fwd_kernel(*refs, cfg: HyftConfig, sm_scale: float,
                       quantized: bool):
    if quantized:
        q_ref, k_ref, v_ref, ks_ref, vs_ref, mask_ref, acc_ref, m_ref, l_ref = refs
    else:
        q_ref, k_ref, v_ref, mask_ref, acc_ref, m_ref, l_ref = refs
    q = q_ref[0].astype(F32)              # (gp, dh) — GQA group as rows
    k = k_ref[0].astype(F32)              # (bk, dh)
    v = v_ref[0].astype(F32)
    if quantized:                         # dequant fused into the load
        k = k * ks_ref[0][:, None]
        v = v * vs_ref[0][:, None]
    acc, m_loc, l_loc = _decode_tile(q, k, v, mask_ref[0], cfg, sm_scale)
    acc_ref[...] = acc[None, None]
    m_ref[...] = jnp.broadcast_to(m_loc[None, None], m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_loc[None, None], l_ref.shape)


@functools.partial(jax.jit, static_argnames=(
    "cfg", "sm_scale", "block_k", "interpret"))
def flash_hyft_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                      cfg: HyftConfig, sm_scale: float | None = None,
                      block_k: int = 256, interpret: bool = True,
                      kv_len_mask: jax.Array | None = None,
                      k_scale: jax.Array | None = None,
                      v_scale: jax.Array | None = None):
    """Split-K fused decode attention with Hyft softmax (Sq = 1).

    Args:
      q: (B, Hq, 1, D);  k, v: (B, Hkv, Sk, D) float — or int8 FP2FX raws
        with ``k_scale``/``v_scale`` (B, Hkv, Sk) fp32 per-(head, position)
        scales, in which case dequantization fuses into the K/V loads.
      kv_len_mask: optional (B, Sk) validity mask (nonzero = valid); decode
        always masks (cache padding), so a missing mask means all-valid.
    Returns (B, Hq, 1, D) fp32.  Forward-only (decode is not trained
    through); for a single KV split the result is bitwise identical to the
    monolithic fused kernel on the same block.
    """
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    assert Sq == 1 and Hq % Hkv == 0
    g = Hq // Hkv
    scale = sm_scale if sm_scale is not None else D ** -0.5
    bk = min(block_k, -(-Sk // 128) * 128)  # lane-aligned KV blocks
    pad_k = (-Sk) % bk
    maskf = (kv_len_mask.astype(F32) if kv_len_mask is not None
             else jnp.ones((B, Sk), F32))
    if pad_k:
        k = _pad0(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = _pad0(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        maskf = _pad0(maskf, ((0, 0), (0, pad_k)))
        if k_scale is not None:
            k_scale = _pad0(k_scale, ((0, 0), (0, 0), (0, pad_k)))
            v_scale = _pad0(v_scale, ((0, 0), (0, 0), (0, pad_k)))
    Skp = Sk + pad_k
    ns = Skp // bk
    gp = -(-g // 8) * 8  # sublane-aligned group rows

    q3 = q[:, :, 0, :].reshape(B, Hkv, g, D)
    q3 = _pad0(q3, ((0, 0), (0, 0), (0, gp - g), (0, 0)))
    q3 = q3.reshape(B * Hkv, gp, D)
    k3 = k.reshape(B * Hkv, Skp, D)
    v3 = v.reshape(B * Hkv, Skp, D)

    quantized = k_scale is not None
    in_specs = [
        pl.BlockSpec((1, gp, D), lambda b, j: (b, 0, 0)),
        pl.BlockSpec((1, bk, D), lambda b, j: (b, j, 0)),
        pl.BlockSpec((1, bk, D), lambda b, j: (b, j, 0)),
    ]
    operands = [q3, k3, v3]
    if quantized:
        in_specs += [pl.BlockSpec((1, bk), lambda b, j: (b, j))] * 2
        operands += [k_scale.reshape(B * Hkv, Skp),
                     v_scale.reshape(B * Hkv, Skp)]
    in_specs.append(pl.BlockSpec((1, bk), lambda b, j, h=Hkv: (b // h, j)))
    operands.append(maskf)

    acc, m_st, l_st = pl.pallas_call(
        functools.partial(_decode_fwd_kernel, cfg=cfg, sm_scale=scale,
                          quantized=quantized),
        grid=(B * Hkv, ns),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, gp, D), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((1, 1, gp, 128), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((1, 1, gp, 128), lambda b, j: (b, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * Hkv, ns, gp, D), F32),
            jax.ShapeDtypeStruct((B * Hkv, ns, gp, 128), I32),
            jax.ShapeDtypeStruct((B * Hkv, ns, gp, 128), F32),
        ],
        interpret=interpret,
    )(*operands)

    # ---- L2: integer-max / fixed-sum tree combine across KV splits
    out = _splitk_combine(acc, m_st, l_st, cfg)
    return out[:, :g].reshape(B, Hkv, g, D).reshape(B, Hq, 1, D)


# --------------------------------------------------------------------------
# paged decode kernel (Sq = 1, block-table K/V gather)
# --------------------------------------------------------------------------
#
# The split-K decode kernel assumes a contiguous (B, Hkv, Sk, D) KV stripe
# per sequence.  The paged serving layout instead keeps one global pool of
# fixed-size pages — (n_pages, Hkv, page_size, D), dense or int8 fp2fx8 —
# and a per-sequence block table mapping virtual KV block j to a physical
# page.  The kernel below is the same split-K machine with pages as splits:
# the block table rides in as a scalar-prefetch operand so the BlockSpec
# index maps can route grid step (b, j) to physical page bt[b, j] (the DMA
# for page j+1 issues while page j computes — on TPU the gather is free).
# Each page emits the same local (max, fixed-sum, acc) stats via
# ``_decode_tile`` and the combine is ``_splitk_combine`` — so with pages
# laid out sequentially (bt[b, j] == j over a contiguous pool) the result
# is bitwise identical to ``flash_hyft_decode`` at block_k == page_size.


def _paged_decode_kernel(*refs, cfg: HyftConfig, sm_scale: float,
                         quantized: bool):
    if quantized:
        (bt_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, mask_ref,
         acc_ref, m_ref, l_ref) = refs
    else:
        bt_ref, q_ref, k_ref, v_ref, mask_ref, acc_ref, m_ref, l_ref = refs
    del bt_ref  # consumed by the index maps (scalar prefetch)
    q = q_ref[0].astype(F32)              # (gp, dh)
    k = k_ref[0, 0].astype(F32)           # (ps, dh) — one physical page
    v = v_ref[0, 0].astype(F32)
    if quantized:                         # dequant fused into the page load
        k = k * ks_ref[0, 0][:, None]
        v = v * vs_ref[0, 0][:, None]
    acc, m_loc, l_loc = _decode_tile(q, k, v, mask_ref[0], cfg, sm_scale)
    acc_ref[...] = acc[None, None]
    m_ref[...] = jnp.broadcast_to(m_loc[None, None], m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_loc[None, None], l_ref.shape)


@functools.partial(jax.jit, static_argnames=("cfg", "sm_scale", "interpret"))
def flash_hyft_decode_paged(q: jax.Array, k_pages: jax.Array,
                            v_pages: jax.Array, block_tables: jax.Array,
                            cfg: HyftConfig, sm_scale: float | None = None,
                            interpret: bool = True,
                            kv_len_mask: jax.Array | None = None,
                            k_scale: jax.Array | None = None,
                            v_scale: jax.Array | None = None):
    """Split-K fused decode attention over a paged KV pool (Sq = 1).

    Args:
      q: (B, Hq, 1, D);  k_pages, v_pages: (n_pages, Hkv, page_size, D)
        float — or int8 FP2FX raws with ``k_scale``/``v_scale``
        (n_pages, Hkv, page_size) fp32 scales (the fp2fx8 page layout),
        in which case dequantization fuses into the page loads.
      block_tables: (B, nb) int32 — virtual KV block j of sequence b lives
        in physical page ``block_tables[b, j]`` (scalar-prefetched so the
        grid's BlockSpec index maps do the gather).
      kv_len_mask: optional (B, nb * page_size) validity mask over the
        *virtual* KV axis (nonzero = valid); missing means all-valid.
    Returns (B, Hq, 1, D) fp32.  With ``block_tables[b, j] == j`` over a
    contiguous pool this is bitwise identical to ``flash_hyft_decode`` at
    ``block_k == page_size`` (same tile arithmetic, same combine order).
    """
    from jax.experimental.pallas import tpu as pltpu

    B, Hq, Sq, D = q.shape
    _, Hkv, ps, _ = k_pages.shape
    nb = block_tables.shape[1]
    assert Sq == 1 and Hq % Hkv == 0
    g = Hq // Hkv
    scale = sm_scale if sm_scale is not None else D ** -0.5
    gp = -(-g // 8) * 8  # sublane-aligned group rows
    Lv = nb * ps         # virtual KV length
    maskf = (kv_len_mask.astype(F32) if kv_len_mask is not None
             else jnp.ones((B, Lv), F32))

    q3 = q[:, :, 0, :].reshape(B, Hkv, g, D)
    q3 = _pad0(q3, ((0, 0), (0, 0), (0, gp - g), (0, 0)))
    q3 = q3.reshape(B * Hkv, gp, D)

    quantized = k_scale is not None
    in_specs = [
        pl.BlockSpec((1, gp, D), lambda b, j, bt: (b, 0, 0)),
        pl.BlockSpec((1, 1, ps, D),
                     lambda b, j, bt, h=Hkv: (bt[b // h, j], b % h, 0, 0)),
        pl.BlockSpec((1, 1, ps, D),
                     lambda b, j, bt, h=Hkv: (bt[b // h, j], b % h, 0, 0)),
    ]
    operands = [q3, k_pages, v_pages]
    if quantized:
        in_specs += [pl.BlockSpec(
            (1, 1, ps), lambda b, j, bt, h=Hkv: (bt[b // h, j], b % h, 0))] * 2
        operands += [k_scale, v_scale]
    in_specs.append(pl.BlockSpec((1, ps), lambda b, j, bt, h=Hkv: (b // h, j)))
    operands.append(maskf)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B * Hkv, nb),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, gp, D), lambda b, j, bt: (b, j, 0, 0)),
            pl.BlockSpec((1, 1, gp, 128), lambda b, j, bt: (b, j, 0, 0)),
            pl.BlockSpec((1, 1, gp, 128), lambda b, j, bt: (b, j, 0, 0)),
        ],
    )
    acc, m_st, l_st = pl.pallas_call(
        functools.partial(_paged_decode_kernel, cfg=cfg, sm_scale=scale,
                          quantized=quantized),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B * Hkv, nb, gp, D), F32),
            jax.ShapeDtypeStruct((B * Hkv, nb, gp, 128), I32),
            jax.ShapeDtypeStruct((B * Hkv, nb, gp, 128), F32),
        ],
        interpret=interpret,
    )(block_tables.astype(I32), *operands)

    out = _splitk_combine(acc, m_st, l_st, cfg)
    return out[:, :g].reshape(B, Hkv, g, D).reshape(B, Hq, 1, D)


# --------------------------------------------------------------------------
# speculative-decode verify kernel (Sq = K + 1 draft tokens per slot)
# --------------------------------------------------------------------------
#
# Speculative decoding turns K one-token decode steps into ONE prefill-shaped
# verification: the model scores [last_token, draft_1..draft_K] in a single
# pass and keeps the longest accepted prefix.  That is exactly the regime the
# Hyft pipeline amortizes best — the softmax work is batched along the
# sequence axis, so the per-token share of stage-1/2/3 overhead drops by the
# draft length (the same observation Vasyltsov & Chang make for batched
# softmax approximation).
#
# The kernel is the split-K decode machine with the draft axis folded into
# the tile rows alongside the GQA group: q rows enumerate (group member,
# draft position), every row shares each K/V block load, and each split
# emits the same local (max, fixed-sum, acc) stats through ``_decode_tile``
# merged by ``_splitk_combine``.  The ONLY new ingredient is the mask: draft
# token t sits at cache position pos+t and must see exactly [0, pos+t] —
# a per-ROW validity mask (causal within the draft, ragged lengths across
# the batch) instead of the decode kernel's per-slot row.  The caller
# supplies it as (B, Sq, Lk); it rides in un-duplicated (the mask depends
# only on the draft lane) and expands over the GQA group inside the tile,
# so at Sq == 1 the kernel is bitwise identical to ``flash_hyft_decode`` /
# ``flash_hyft_decode_paged`` on the same splits.
#
# Both KV layouts are served by one entry point: contiguous (B, Hkv, Sk, D)
# stripes split by ``block_k``, or a paged pool + scalar-prefetched block
# tables with pages as splits.  fp2fx8 dequantization fuses into the K/V
# loads exactly as in the decode kernels.


def _verify_mask_rows(mask, group: int):
    """(sp, bk) per-draft-lane mask -> (group * sp, bk) tile rows.  The
    mask depends only on the draft lane, so it rides in UN-duplicated and
    expands over the GQA group inside the tile (a VMEM broadcast) instead
    of streaming a group-fold redundant HBM buffer."""
    sp, bk = mask.shape
    return jnp.broadcast_to(mask[None], (group, sp, bk)).reshape(
        group * sp, bk)


def _verify_fwd_kernel(*refs, cfg: HyftConfig, sm_scale: float,
                       quantized: bool, group: int):
    if quantized:
        q_ref, k_ref, v_ref, ks_ref, vs_ref, mask_ref, acc_ref, m_ref, l_ref = refs
    else:
        q_ref, k_ref, v_ref, mask_ref, acc_ref, m_ref, l_ref = refs
    q = q_ref[0].astype(F32)              # (rows, dh) — (group, draft) rows
    k = k_ref[0].astype(F32)              # (bk, dh)
    v = v_ref[0].astype(F32)
    if quantized:                         # dequant fused into the load
        k = k * ks_ref[0][:, None]
        v = v * vs_ref[0][:, None]
    mask = _verify_mask_rows(mask_ref[0], group)
    acc, m_loc, l_loc = _decode_tile(q, k, v, mask, cfg, sm_scale)
    acc_ref[...] = acc[None, None]
    m_ref[...] = jnp.broadcast_to(m_loc[None, None], m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_loc[None, None], l_ref.shape)


def _verify_paged_kernel(*refs, cfg: HyftConfig, sm_scale: float,
                         quantized: bool, group: int):
    if quantized:
        (bt_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, mask_ref,
         acc_ref, m_ref, l_ref) = refs
    else:
        bt_ref, q_ref, k_ref, v_ref, mask_ref, acc_ref, m_ref, l_ref = refs
    del bt_ref  # consumed by the index maps (scalar prefetch)
    q = q_ref[0].astype(F32)              # (rows, dh)
    k = k_ref[0, 0].astype(F32)           # (ps, dh) — one physical page
    v = v_ref[0, 0].astype(F32)
    if quantized:                         # dequant fused into the page load
        k = k * ks_ref[0, 0][:, None]
        v = v * vs_ref[0, 0][:, None]
    mask = _verify_mask_rows(mask_ref[0], group)
    acc, m_loc, l_loc = _decode_tile(q, k, v, mask, cfg, sm_scale)
    acc_ref[...] = acc[None, None]
    m_ref[...] = jnp.broadcast_to(m_loc[None, None], m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_loc[None, None], l_ref.shape)


@functools.partial(jax.jit, static_argnames=(
    "cfg", "sm_scale", "block_k", "interpret"))
def flash_hyft_verify(q: jax.Array, k: jax.Array, v: jax.Array,
                      kv_pos_mask: jax.Array, cfg: HyftConfig,
                      sm_scale: float | None = None, block_k: int = 256,
                      interpret: bool = True,
                      block_tables: jax.Array | None = None,
                      k_scale: jax.Array | None = None,
                      v_scale: jax.Array | None = None):
    """Split-K fused chunk attention with Hyft softmax (Sq = token chunk).

    The kernel behind ``verify_attention``'s kernel mode, and through it
    ``model.prefill_chunk`` (DESIGN.md §12): prompt-chunk prefill,
    prefix-hit suffixes, and speculative-decode verify (Sq = draft_k + 1)
    all lower to this one entry.

    Args:
      q: (B, Hq, Sq, D) — the chunk's queries (for verify, the
        [last_token, draft_1..draft_K] lanes).
      k, v: contiguous (B, Hkv, Sk, D) stripes, or — with ``block_tables``
        (B, nb) — a paged pool (n_pages, Hkv, page_size, D).  Either layout
        may be int8 FP2FX raws with ``k_scale``/``v_scale`` fp32 scales
        (dequantization fuses into the loads).
      kv_pos_mask: (B, Sq, Lk) per-draft-token validity over the (virtual)
        KV axis, nonzero = visible — the causal-within-draft mask
        ``kv_index <= pos + t`` plus any cache-length masking.  Ragged
        draft lengths across the batch ride in here (a padded draft row's
        outputs are discarded by the caller).
    Returns (B, Hq, Sq, D) fp32.  Forward-only.  At Sq == 1 this is bitwise
    identical to ``flash_hyft_decode`` (same splits) / ``_decode_paged``
    (pages as splits): the tile arithmetic is the shared ``_decode_tile``
    and the combine the shared ``_splitk_combine``; only the mask gained a
    row axis.
    """
    B, Hq, Sq, D = q.shape
    Hkv = k.shape[1]
    assert Hq % Hkv == 0
    g = Hq // Hkv
    scale = sm_scale if sm_scale is not None else D ** -0.5
    sp = -(-Sq // 8) * 8                  # sublane-aligned draft rows
    rows = g * sp                         # tile rows: (group, draft) folded
    maskf = kv_pos_mask.astype(F32)       # (B, Sq, Lk)

    q3 = q.reshape(B, Hkv, g, Sq, D)
    q3 = _pad0(q3, ((0, 0), (0, 0), (0, 0), (0, sp - Sq), (0, 0)))
    q3 = q3.reshape(B * Hkv, rows, D)

    quantized = k_scale is not None

    if block_tables is not None:  # ---- paged layout: pages as splits ----
        from jax.experimental.pallas import tpu as pltpu

        ps = k.shape[2]
        nb = block_tables.shape[1]
        maskE = _pad0(maskf, ((0, 0), (0, sp - Sq), (0, 0)))  # (B, sp, Lv)
        in_specs = [
            pl.BlockSpec((1, rows, D), lambda b, j, bt: (b, 0, 0)),
            pl.BlockSpec((1, 1, ps, D),
                         lambda b, j, bt, h=Hkv: (bt[b // h, j], b % h, 0, 0)),
            pl.BlockSpec((1, 1, ps, D),
                         lambda b, j, bt, h=Hkv: (bt[b // h, j], b % h, 0, 0)),
        ]
        operands = [q3, k, v]
        if quantized:
            in_specs += [pl.BlockSpec(
                (1, 1, ps),
                lambda b, j, bt, h=Hkv: (bt[b // h, j], b % h, 0))] * 2
            operands += [k_scale, v_scale]
        in_specs.append(
            pl.BlockSpec((1, sp, ps), lambda b, j, bt, h=Hkv: (b // h, 0, j)))
        operands.append(maskE)
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B * Hkv, nb),
            in_specs=in_specs,
            out_specs=[
                pl.BlockSpec((1, 1, rows, D), lambda b, j, bt: (b, j, 0, 0)),
                pl.BlockSpec((1, 1, rows, 128), lambda b, j, bt: (b, j, 0, 0)),
                pl.BlockSpec((1, 1, rows, 128), lambda b, j, bt: (b, j, 0, 0)),
            ],
        )
        acc, m_st, l_st = pl.pallas_call(
            functools.partial(_verify_paged_kernel, cfg=cfg, sm_scale=scale,
                              quantized=quantized, group=g),
            grid_spec=grid_spec,
            out_shape=[
                jax.ShapeDtypeStruct((B * Hkv, nb, rows, D), F32),
                jax.ShapeDtypeStruct((B * Hkv, nb, rows, 128), I32),
                jax.ShapeDtypeStruct((B * Hkv, nb, rows, 128), F32),
            ],
            interpret=interpret,
        )(block_tables.astype(I32), *operands)
    else:  # ---- contiguous layout: block_k splits, as flash_hyft_decode ----
        Sk = k.shape[2]
        bk = min(block_k, -(-Sk // 128) * 128)  # lane-aligned KV blocks
        pad_k = (-Sk) % bk
        if pad_k:
            k = _pad0(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
            v = _pad0(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
            maskf = _pad0(maskf, ((0, 0), (0, 0), (0, pad_k)))
            if quantized:
                k_scale = _pad0(k_scale, ((0, 0), (0, 0), (0, pad_k)))
                v_scale = _pad0(v_scale, ((0, 0), (0, 0), (0, pad_k)))
        Skp = Sk + pad_k
        ns = Skp // bk
        maskE = _pad0(maskf, ((0, 0), (0, sp - Sq), (0, 0)))  # (B, sp, Skp)
        in_specs = [
            pl.BlockSpec((1, rows, D), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, bk, D), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, j: (b, j, 0)),
        ]
        operands = [q3, k.reshape(B * Hkv, Skp, D), v.reshape(B * Hkv, Skp, D)]
        if quantized:
            in_specs += [pl.BlockSpec((1, bk), lambda b, j: (b, j))] * 2
            operands += [k_scale.reshape(B * Hkv, Skp),
                         v_scale.reshape(B * Hkv, Skp)]
        in_specs.append(
            pl.BlockSpec((1, sp, bk), lambda b, j, h=Hkv: (b // h, 0, j)))
        operands.append(maskE)
        acc, m_st, l_st = pl.pallas_call(
            functools.partial(_verify_fwd_kernel, cfg=cfg, sm_scale=scale,
                              quantized=quantized, group=g),
            grid=(B * Hkv, ns),
            in_specs=in_specs,
            out_specs=[
                pl.BlockSpec((1, 1, rows, D), lambda b, j: (b, j, 0, 0)),
                pl.BlockSpec((1, 1, rows, 128), lambda b, j: (b, j, 0, 0)),
                pl.BlockSpec((1, 1, rows, 128), lambda b, j: (b, j, 0, 0)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((B * Hkv, ns, rows, D), F32),
                jax.ShapeDtypeStruct((B * Hkv, ns, rows, 128), I32),
                jax.ShapeDtypeStruct((B * Hkv, ns, rows, 128), F32),
            ],
            interpret=interpret,
        )(*operands)

    out = _splitk_combine(acc, m_st, l_st, cfg)        # (BH, rows, D)
    out = out.reshape(B, Hkv, g, sp, D)[:, :, :, :Sq]
    return out.reshape(B, Hq, Sq, D)
