"""Fused flash attention with Hyft softmax — the TPU-native form of §3.6.

The paper pipelines softmax's three stages (max | exp+sum | div) *across
vectors* because one vector's stages are sequential.  On TPU the same row
independence is exploited the opposite way: we stream KV blocks through VMEM
and maintain *online* (max, sum, acc) state per query row, so stage 1/2/3 of
consecutive blocks overlap inside one kernel — one HBM pass over K/V instead
of the three passes an unfused QK^T -> softmax -> PV takes.  The paper's
L1/L2 tree of Hyft units (Fig. 6) is exactly the associative (max,sum) merge
used here blockwise (and cross-device in ``repro.models.attention``'s
sequence-parallel decode).

All softmax arithmetic inside is Hyft's: FP2FX, Booth shift-add, field
assembly, fixed-point accumulation, and the final log-subtract division.
The online rescale multiplies by the *Hyft-approximated* exp of the max
delta (the DIV/MUL unit in rescale duty).

Accumulator pattern: (bh, q, kv) grid with kv innermost; output blocks and
the (m, l) stat blocks map to the same index for every kv step, so they stay
resident in VMEM and serve as carry; finalization happens at the last step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import numerics as nm
from repro.core.hyft import HyftConfig

F32 = jnp.float32
I32 = jnp.int32
NEG_BIG = -3.0e38  # pre-quantization mask value; FP2FX saturates it to fx lo


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
                  cfg: HyftConfig, sm_scale: float, causal: bool,
                  block_q: int, block_k: int, nk: int):
    iq, ik = pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, -(2 ** (cfg.total_bits - 1)))
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(F32)              # (bq, dh)
    k = k_ref[0].astype(F32)              # (bk, dh)
    v = v_ref[0].astype(F32)              # (bk, dh)
    z = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=F32) * sm_scale
    if causal:
        qi = iq * block_q + jax.lax.broadcasted_iota(I32, z.shape, 0)
        ki = ik * block_k + jax.lax.broadcasted_iota(I32, z.shape, 1)
        z = jnp.where(qi >= ki, z, NEG_BIG)

    # ---- Hyft stage 1: FP2FX + (strided) block max, merged with running max
    z_raw = nm.fp2fx(z, cfg.frac_bits, cfg.total_bits)
    zsub = z_raw[:, :: cfg.step] if cfg.step > 1 else z_raw
    blk_max = jnp.max(zsub, axis=-1, keepdims=True)
    m_old = m_ref[:, :1]
    m_new = jnp.maximum(m_old, blk_max)

    # ---- Hyft stage 2: exponent unit + fixed-point accumulation
    e, m = nm.exp_unit(z_raw - m_new, cfg.frac_bits, cfg.mant_bits)
    addend = nm.expfloat_to_fx(e, m, cfg.mant_bits, cfg.acc_bits)
    l_blk = jnp.sum(addend, axis=-1, keepdims=True)

    # online rescale of the carried sum/acc by the *Hyft* exp of the max delta
    e_a, m_a = nm.exp_unit(m_old - m_new, cfg.frac_bits, cfg.mant_bits)
    alpha = ((1 << cfg.mant_bits) + m_a).astype(F32) * nm.pow2_float(e_a - cfg.mant_bits)
    l_new = nm.fx_quantize(l_ref[:, :1] * alpha, cfg.acc_bits) + l_blk

    # ---- probabilities as assembled floats -> MXU matmul with V
    p = ((1 << cfg.mant_bits) + m).astype(F32) * nm.pow2_float(e - cfg.mant_bits)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=F32)
    acc = o_ref[0].astype(F32) * alpha + pv

    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)
    o_ref[...] = acc[None].astype(o_ref.dtype)

    # ---- Hyft stage 3: log-subtract division at the last kv step
    @pl.when(ik == nk - 1)
    def _finalize():
        e_b, m_b = nm.lod_refloat(l_ref[:, :1], cfg.mant_bits)
        num = o_ref[0].astype(F32)
        sg, e_n, m_n = nm.float_fields(num, cfg.mant_bits)
        res = nm.log_div(e_n, m_n, e_b, m_b, cfg.mant_bits)
        res = jnp.where(sg == 1, -res, res)
        res = jnp.where(num == 0.0, 0.0, res)
        o_ref[...] = res[None].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "cfg", "sm_scale", "causal", "block_q", "block_k", "interpret", "return_stats"))
def flash_hyft_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                         cfg: HyftConfig, sm_scale: float | None = None,
                         causal: bool = True, block_q: int = 128,
                         block_k: int = 128, interpret: bool = True,
                         return_stats: bool = False):
    """Fused attention with Hyft softmax.

    Args:
      q: (B, Hq, Sq, D);  k, v: (B, Hkv, Sk, D) with Hq % Hkv == 0 (GQA).
    Returns (B, Hq, Sq, D) in fp32 (callers cast), plus (m, l) row stats when
    ``return_stats`` (used by the cross-device sequence-parallel combine).
    """
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    scale = sm_scale if sm_scale is not None else D ** -0.5
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, "pad sequence to block multiples"
    q3 = q.reshape(B * Hq, Sq, D)
    k3 = k.reshape(B * Hkv, Sk, D)
    v3 = v.reshape(B * Hkv, Sk, D)
    nq, nk = Sq // bq, Sk // bk
    grid = (B * Hq, nq, nk)

    kern = functools.partial(_flash_kernel, cfg=cfg, sm_scale=scale,
                             causal=causal, block_q=bq, block_k=bk, nk=nk)
    o, m_st, l_st = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j, g=group: (b // g, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((bq, 128), lambda b, i, j, n=nq: (b * n + i, 0)),
            pl.BlockSpec((bq, 128), lambda b, i, j, n=nq: (b * n + i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * Hq, Sq, D), F32),
            jax.ShapeDtypeStruct((B * Hq * Sq, 128), I32),
            jax.ShapeDtypeStruct((B * Hq * Sq, 128), F32),
        ],
        interpret=interpret,
    )(q3, k3, v3)
    out = o.reshape(B, Hq, Sq, D)
    if return_stats:
        return out, m_st[:, 0].reshape(B, Hq, Sq), l_st[:, 0].reshape(B, Hq, Sq)
    return out
