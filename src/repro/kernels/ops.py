"""Public jit'd wrappers around the Pallas kernels.

``interpret`` defaults to auto: compiled on TPU, interpreter elsewhere (this
container is CPU-only; TPU is the lowering target).  ``hyft_softmax`` is
differentiable — its VJP is the backward *kernel* (the accelerator's reused
DIV/MUL datapath), mirroring ``repro.core.hyft.hyft_softmax``.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.core.hyft import HyftConfig
from repro.kernels import hyft_softmax as _hk
from repro.kernels.flash_attention import flash_hyft_attention  # noqa: F401


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def hyft_softmax(z: jax.Array, cfg: HyftConfig) -> jax.Array:
    return _hk.hyft_softmax_fwd_kernel(z, cfg, interpret=_auto_interpret())


import jax.numpy as jnp


def _fwd(z, cfg):
    s = _hk.hyft_softmax_fwd_kernel(z, cfg, interpret=_auto_interpret())
    return s, (s, jnp.zeros((0,), z.dtype))


def _bwd(cfg, res, dy):
    s, dt_marker = res
    dz = _hk.hyft_softmax_bwd_kernel(s, dy, cfg, interpret=_auto_interpret())
    return (dz.astype(dt_marker.dtype),)


hyft_softmax.defvjp(_fwd, _bwd)


def hyft_attention(q, k, v, cfg: HyftConfig, sm_scale=None, causal=True,
                   block_q=128, block_k=128):
    """Fused flash attention with Hyft softmax (forward; serving/prefill)."""
    return flash_hyft_attention(q, k, v, cfg, sm_scale=sm_scale, causal=causal,
                                block_q=block_q, block_k=block_k,
                                interpret=_auto_interpret())
