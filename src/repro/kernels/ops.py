"""Public jit'd wrappers around the Pallas kernels + the attention contract.

``interpret`` defaults to auto: compiled on TPU, interpreter elsewhere (this
container is CPU-only; TPU is the lowering target).  ``hyft_softmax`` is
differentiable — its VJP is the backward *kernel* (the accelerator's reused
DIV/MUL datapath), mirroring ``repro.core.hyft.hyft_softmax``.

Mask/stats contract (DESIGN.md §3) — shared by all three attention modes
(``unfused`` / ``chunked`` / ``kernel``):

  * ``kv_len_mask``: optional per-batch KV validity mask of shape (B, Sk);
    bool or float, nonzero = valid.  Masking is applied to the *float scores
    before FP2FX* so invalid positions saturate to the fixed-point minimum
    and their Hyft probability flushes to zero.  ``as_mask_f`` normalizes it
    to float32 once, at the dispatch boundary, so the differentiable paths
    (custom_vjp) see a float-typed side input with a well-defined zero
    cotangent.
  * ``q_offset``: static int added to query positions for the causal mask.
  * row stats: every online mode carries per-row ``(m, l)`` — the int32
    fixed-point running max and the fp32 fixed-point probability sum — and
    the fused kernel saves exactly these as its backward residuals
    (``return_stats`` exposes them for the cross-device combine).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.hyft import HyftConfig
from repro.kernels import hyft_softmax as _hk
from repro.kernels.flash_attention import (  # noqa: F401
    flash_hyft_attention, flash_hyft_decode, flash_hyft_decode_paged,
    flash_hyft_verify)

F32 = jnp.float32


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def as_mask_f(kv_len_mask) -> jax.Array | None:
    """Normalize a KV validity mask (bool/int/float or None) to float32."""
    if kv_len_mask is None:
        return None
    return kv_len_mask.astype(F32)


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def hyft_softmax(z: jax.Array, cfg: HyftConfig) -> jax.Array:
    return _hk.hyft_softmax_fwd_kernel(z, cfg, interpret=_auto_interpret())


def _fwd(z, cfg):
    s = _hk.hyft_softmax_fwd_kernel(z, cfg, interpret=_auto_interpret())
    return s, (s, jnp.zeros((0,), z.dtype))


def _bwd(cfg, res, dy):
    s, dt_marker = res
    dz = _hk.hyft_softmax_bwd_kernel(s, dy, cfg, interpret=_auto_interpret())
    return (dz.astype(dt_marker.dtype),)


hyft_softmax.defvjp(_fwd, _bwd)


def hyft_attention(q, k, v, cfg: HyftConfig, sm_scale=None, causal=True,
                   block_q=128, block_k=128, kv_len_mask=None, q_offset=0,
                   return_stats=False):
    """Fused flash attention with Hyft softmax — trainable and mask-aware.

    The production ``attn_mode="kernel"`` path for prefill, decode (pass the
    cache validity mask as ``kv_len_mask``) and training (differentiable via
    the fused Pallas backward kernels).
    """
    return flash_hyft_attention(q, k, v, cfg, sm_scale=sm_scale, causal=causal,
                                block_q=block_q, block_k=block_k,
                                interpret=_auto_interpret(),
                                return_stats=return_stats,
                                kv_len_mask=as_mask_f(kv_len_mask),
                                q_offset=q_offset)


def hyft_decode_attention(q, k, v, cfg: HyftConfig, sm_scale=None,
                          block_k=256, kv_len_mask=None, k_scale=None,
                          v_scale=None):
    """Split-K fused decode attention (Sq = 1) with Hyft softmax.

    The serving fast path: the KV axis is split across the kernel grid, each
    split emits local Hyft (max, fixed-sum, acc) stats, and the cross-split
    combine is the paper's L1/L2 tree (integer max + rescaled fixed sums).
    Pass int8 ``k``/``v`` with ``k_scale``/``v_scale`` (the fp2fx8 KV-cache
    layout) to fuse dequantization into the K/V loads.
    """
    return flash_hyft_decode(q, k, v, cfg, sm_scale=sm_scale, block_k=block_k,
                             interpret=_auto_interpret(),
                             kv_len_mask=as_mask_f(kv_len_mask),
                             k_scale=k_scale, v_scale=v_scale)


def hyft_paged_decode_attention(q, k_pages, v_pages, block_tables,
                                cfg: HyftConfig, sm_scale=None,
                                kv_len_mask=None, k_scale=None, v_scale=None):
    """Split-K fused decode attention over a paged KV pool (Sq = 1).

    The block table is scalar-prefetched so the kernel's index maps gather
    physical pages directly; each page emits local Hyft (max, fixed-sum,
    acc) stats and the cross-page combine is the same L1/L2 tree as the
    contiguous split-K kernel — bitwise-equal to it when pages are laid out
    sequentially.  Pass int8 pages + ``k_scale``/``v_scale`` pools (the
    fp2fx8 page layout) to fuse dequantization into the page loads.
    """
    return flash_hyft_decode_paged(q, k_pages, v_pages, block_tables, cfg,
                                   sm_scale=sm_scale,
                                   interpret=_auto_interpret(),
                                   kv_len_mask=as_mask_f(kv_len_mask),
                                   k_scale=k_scale, v_scale=v_scale)


def hyft_verify_attention(q, k, v, kv_pos_mask, cfg: HyftConfig,
                          sm_scale=None, block_k=256, block_tables=None,
                          k_scale=None, v_scale=None):
    """Split-K fused verify attention (Sq = draft chunk) with Hyft softmax.

    The speculative-decoding fast path: scores the [last_token, drafts]
    chunk of every slot in one kernel call, with a per-draft-token
    ``kv_pos_mask`` (B, Sq, Lk) carrying the causal-within-draft frontier
    and ragged draft lengths.  ``block_tables`` switches K/V to the paged
    pool layout (pages as splits); int8 K/V with ``k_scale``/``v_scale``
    fuse fp2fx8 dequantization into the loads.  At Sq == 1 this is bitwise
    identical to the decode kernels on the same splits.
    """
    return flash_hyft_verify(q, k, v, as_mask_f(kv_pos_mask), cfg,
                             sm_scale=sm_scale, block_k=block_k,
                             interpret=_auto_interpret(),
                             block_tables=block_tables,
                             k_scale=k_scale, v_scale=v_scale)
