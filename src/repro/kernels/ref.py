"""Pure-jnp oracles for every Pallas kernel (bit-exact references).

``hyft_softmax_ref`` / ``hyft_softmax_bwd_ref`` are the core emulation (the
kernels trace the identical arithmetic, so equality is bitwise).
``flash_hyft_attention_ref`` replays the *blocked online* algorithm of the
fused kernel in plain jnp with the same block sizes — also bitwise — and
``attention_ref`` is the unfused mathematical reference (tolerance-based
comparison, quantifying the online-rescale drift).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import numerics as nm
from repro.core.hyft import HyftConfig, hyft_softmax_bwd, hyft_softmax_fwd

F32 = jnp.float32
I32 = jnp.int32
NEG_BIG = -3.0e38


def hyft_softmax_ref(z: jax.Array, cfg: HyftConfig) -> jax.Array:
    return hyft_softmax_fwd(z, cfg)


def hyft_softmax_bwd_ref(s: jax.Array, dy: jax.Array, cfg: HyftConfig) -> jax.Array:
    return hyft_softmax_bwd(s, dy, cfg)


def attention_ref(q, k, v, cfg: HyftConfig | None, sm_scale=None, causal=True,
                  softmax_fn=None):
    """Unfused attention: QK^T -> (hyft|exact) softmax -> PV, with GQA."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    scale = sm_scale if sm_scale is not None else D ** -0.5
    kr = jnp.repeat(k, Hq // Hkv, axis=1)
    vr = jnp.repeat(v, Hq // Hkv, axis=1)
    z = jnp.einsum("bhqd,bhkd->bhqk", q.astype(F32), kr.astype(F32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Sk), bool), k=Sk - Sq)
        z = jnp.where(mask, z, NEG_BIG)
    if softmax_fn is not None:
        p = softmax_fn(z)
    elif cfg is None:
        p = jax.nn.softmax(z, axis=-1)
    else:
        p = hyft_softmax_fwd(z, cfg).astype(F32)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(F32))


def flash_hyft_attention_ref(q, k, v, cfg: HyftConfig, sm_scale=None,
                             causal=True, block_q=128, block_k=128):
    """Blocked oracle: replays the fused kernel's online algorithm in jnp."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    group = Hq // Hkv
    scale = sm_scale if sm_scale is not None else D ** -0.5
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    nq, nk = Sq // bq, Sk // bk
    q3 = q.reshape(B * Hq, Sq, D).astype(F32)
    k3 = k.reshape(B * Hkv, Sk, D).astype(F32)
    v3 = v.reshape(B * Hkv, Sk, D).astype(F32)
    out = jnp.zeros((B * Hq, Sq, D), F32)

    for b in range(B * Hq):
        for i in range(nq):
            qt = q3[b, i * bq:(i + 1) * bq]
            m_run = jnp.full((bq, 1), -(2 ** (cfg.total_bits - 1)), I32)
            l_run = jnp.zeros((bq, 1), F32)
            acc = jnp.zeros((bq, D), F32)
            for j in range(nk):
                kt = k3[b // group, j * bk:(j + 1) * bk]
                vt = v3[b // group, j * bk:(j + 1) * bk]
                z = (qt @ kt.T) * scale
                if causal:
                    qi = i * bq + jnp.arange(bq)[:, None]
                    ki = j * bk + jnp.arange(bk)[None, :]
                    z = jnp.where(qi >= ki, z, NEG_BIG)
                z_raw = nm.fp2fx(z, cfg.frac_bits, cfg.total_bits)
                zsub = z_raw[:, :: cfg.step] if cfg.step > 1 else z_raw
                blk_max = jnp.max(zsub, axis=-1, keepdims=True)
                m_new = jnp.maximum(m_run, blk_max)
                e, m = nm.exp_unit(z_raw - m_new, cfg.frac_bits, cfg.mant_bits)
                addend = nm.expfloat_to_fx(e, m, cfg.mant_bits, cfg.acc_bits)
                l_blk = jnp.sum(addend, axis=-1, keepdims=True)
                e_a, m_a = nm.exp_unit(m_run - m_new, cfg.frac_bits, cfg.mant_bits)
                alpha = ((1 << cfg.mant_bits) + m_a).astype(F32) * \
                    nm.pow2_float(e_a - cfg.mant_bits)
                l_run = nm.fx_quantize(l_run * alpha, cfg.acc_bits) + l_blk
                p = ((1 << cfg.mant_bits) + m).astype(F32) * \
                    nm.pow2_float(e - cfg.mant_bits)
                acc = acc * alpha + p @ vt
                m_run = m_new
            e_b, m_b = nm.lod_refloat(l_run, cfg.mant_bits)
            sg, e_n, m_n = nm.float_fields(acc, cfg.mant_bits)
            res = nm.log_div(e_n, m_n, e_b, m_b, cfg.mant_bits)
            res = jnp.where(sg == 1, -res, res)
            res = jnp.where(acc == 0.0, 0.0, res)
            out = out.at[b, i * bq:(i + 1) * bq].set(res)
    return out.reshape(B, Hq, Sq, D)
