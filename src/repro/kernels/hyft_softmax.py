"""Pallas TPU kernels for Hyft softmax (forward and backward).

TPU adaptation of the accelerator datapath (DESIGN.md §2): the row tile lives
in VMEM; every hardware block (FP2FX, Booth shift-add, field assembly, fixed
adder tree, LOD, log-subtract divide) becomes int32 VPU arithmetic on the
bitcast tile — no transcendentals, no FP divides.  The arithmetic is the
*same jnp graph* as the pure-JAX oracle (``repro.core.hyft``), traced inside
the kernel, so kernel and oracle agree bit-for-bit.

Tiling: grid over row blocks, each program owns a ``(block_rows, cols)`` tile
(full row resident — the standalone kernel targets rows that fit VMEM; longer
rows use the fused flash kernel which blocks the row dimension online).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import numerics as nm
from repro.core.hyft import HyftConfig

F32 = jnp.float32


def _fwd_kernel(z_ref, o_ref, *, cfg: HyftConfig):
    z = z_ref[...].astype(F32)
    # --- input pre-processor: FP2FX + (strided) max search -----------------
    z_raw = nm.fp2fx(z, cfg.frac_bits, cfg.total_bits)
    zmax = jnp.max(z_raw[:, :: cfg.step] if cfg.step > 1 else z_raw,
                   axis=-1, keepdims=True)
    # --- hybrid exponent unit: fixed-in, float-fields-out -------------------
    e, m = nm.exp_unit(z_raw - zmax, cfg.frac_bits, cfg.mant_bits)
    # --- hybrid adder tree: FP2FX @ acc_bits, accumulate, LOD refloat -------
    addend = nm.expfloat_to_fx(e, m, cfg.mant_bits, cfg.acc_bits)
    denom = jnp.sum(addend, axis=-1, keepdims=True)
    e_b, m_b = nm.lod_refloat(denom, cfg.mant_bits)
    # --- hybrid DIV unit: log-subtract division ------------------------------
    o_ref[...] = nm.log_div(e, m, e_b, m_b, cfg.mant_bits).astype(o_ref.dtype)


def _bwd_kernel(s_ref, dy_ref, dz_ref, *, cfg: HyftConfig):
    s = s_ref[...].astype(F32)
    dy = dy_ref[...].astype(F32)
    # --- reuse of the DIV/MUL unit as log-domain multiplier (Eq. 10) --------
    prods = nm.log_mul(dy, s, cfg.mant_bits, half_range=True)
    # --- signed fixed-point adder tree for the dot product -------------------
    prods_q = nm.fx_quantize(prods, cfg.bwd_acc_bits)
    dot = jnp.sum(prods_q, axis=-1, keepdims=True)
    diff = nm.fx_quantize(dy, cfg.bwd_acc_bits) - dot
    dz_ref[...] = nm.log_mul(diff, s, cfg.mant_bits, half_range=True).astype(dz_ref.dtype)


def _row_blocks(rows: int, cols: int, block_rows: int | None) -> int:
    """Row-tile size, clamped to the actual row count (a block can never be
    larger than the padded input it tiles)."""
    if block_rows is not None:
        return max(1, min(block_rows, rows))
    # keep in+out+int32 intermediates within ~6 MB of VMEM, MXU-aligned rows
    budget = 6 * 1024 * 1024
    per_row = cols * 4 * 6  # tile + out + ~4 int32 temps
    br = max(8, min(512, budget // max(per_row, 1)))
    return min(max(8, (br // 8) * 8), max(rows, 1))


@functools.partial(jax.jit, static_argnames=("cfg", "block_rows", "interpret"))
def hyft_softmax_fwd_kernel(z: jax.Array, cfg: HyftConfig,
                            block_rows: int | None = None,
                            interpret: bool = True) -> jax.Array:
    """Row-tiled forward kernel. ``z``: (..., cols); softmax over last axis."""
    shape = z.shape
    cols = shape[-1]
    z2 = z.reshape(-1, cols)
    rows = z2.shape[0]
    br = _row_blocks(rows, cols, block_rows)
    pad = (-rows) % br
    if pad:
        z2 = jnp.pad(z2, ((0, pad), (0, 0)))
    grid = (z2.shape[0] // br,)
    out = pl.pallas_call(
        functools.partial(_fwd_kernel, cfg=cfg),
        grid=grid,
        in_specs=[pl.BlockSpec((br, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(z2.shape, cfg.dtype),
        interpret=interpret,
    )(z2)
    if pad:
        out = out[:rows]
    return out.reshape(shape)


@functools.partial(jax.jit, static_argnames=("cfg", "block_rows", "interpret"))
def hyft_softmax_bwd_kernel(s: jax.Array, dy: jax.Array, cfg: HyftConfig,
                            block_rows: int | None = None,
                            interpret: bool = True) -> jax.Array:
    """Row-tiled backward kernel: dz = s * (dy - <dy, s>) in Hyft arithmetic."""
    shape = s.shape
    cols = shape[-1]
    s2, dy2 = s.reshape(-1, cols), dy.reshape(-1, cols)
    rows = s2.shape[0]
    br = _row_blocks(rows, cols, block_rows)
    pad = (-rows) % br
    if pad:
        s2 = jnp.pad(s2, ((0, pad), (0, 0)))
        dy2 = jnp.pad(dy2, ((0, pad), (0, 0)))
    grid = (s2.shape[0] // br,)
    out = pl.pallas_call(
        functools.partial(_bwd_kernel, cfg=cfg),
        grid=grid,
        in_specs=[pl.BlockSpec((br, cols), lambda i: (i, 0)),
                  pl.BlockSpec((br, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(s2.shape, cfg.dtype),
        interpret=interpret,
    )(s2, dy2)
    if pad:
        out = out[:rows]
    return out.reshape(shape)
