"""Training launcher CLI.

Examples:
  # tiny end-to-end run on CPU (see examples/train_tiny_lm.py for the 100M)
  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \
      --steps 50 --global-batch 8 --seq 64 --ckpt-dir /tmp/ckpt

  # production lowering happens through repro.launch.dryrun; on a real fleet
  # this same entry point runs under the cluster scheduler with
  # jax.distributed.initialize() (multi-host) and the production mesh.
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--softmax", default="hyft16")
    ap.add_argument("--attn-mode", default=None,
                    choices=["unfused", "chunked", "kernel"],
                    help="attention path; 'kernel' = fused Pallas fwd+bwd")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--data-mesh", type=int, default=1)
    ap.add_argument("--model-mesh", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    from repro import optim
    from repro.configs import get_config, smoke_config
    from repro.configs.base import TrainConfig
    from repro.data.synthetic import DataConfig, lm_batch
    from repro.distributed import sharding as shd
    from repro.launch.mesh import make_host_mesh
    from repro.models import build_model
    from repro.train.loop import run_train
    from repro.train.state import init_state, state_shardings
    from repro.train.step import build_train_step

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    cfg = cfg.with_(softmax_impl=args.softmax)
    model = build_model(cfg)

    tcfg = TrainConfig(global_batch=args.global_batch, seq_len=args.seq,
                       microbatch=args.microbatch, lr=args.lr,
                       total_steps=args.steps, remat=args.remat,
                       optimizer=args.optimizer, attn_mode=args.attn_mode)
    ocfg = optim.OptConfig(name=args.optimizer, lr=args.lr)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.global_batch, seed=args.seed)

    mesh = make_host_mesh((args.data_mesh, args.model_mesh))
    rules = shd.default_rules(mesh, cfg)
    state_sh = state_shardings(mesh, model, ocfg, rules)
    from repro.configs import input_specs
    from repro.configs.shapes import ShapeSpec
    specs = input_specs(cfg, ShapeSpec("cli", "train", args.seq,
                                       args.global_batch))
    batch_sh = shd.batch_shardings(mesh, specs, rules)

    with mesh:
        state = init_state(model, ocfg, jax.random.PRNGKey(args.seed))
        step = build_train_step(model, tcfg, ocfg, mesh, state_sh, batch_sh)
        state, hist = run_train(state, step, lambda s: lm_batch(dcfg, s),
                                tcfg, ckpt_dir=args.ckpt_dir,
                                state_sh=state_sh)
    print(f"final loss: {hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
