"""Serving launcher CLI: prefill a batch of prompts, decode greedily.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
      --batch 4 --prefill 16 --max-new 16 --softmax hyft16
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--softmax", default="hyft16")
    ap.add_argument("--attn-mode", default=None,
                    choices=["unfused", "chunked", "kernel"],
                    help="attention path; 'kernel' = split-K fused Pallas decode")
    ap.add_argument("--cache-dtype", default="float32",
                    help="KV cache storage: jnp dtype name or 'fp2fx8' "
                         "(int8 FP2FX raws + per-head scales)")
    ap.add_argument("--decode-loop", default="scan",
                    choices=["scan", "host"],
                    help="'scan' = one on-device lax.scan; 'host' = "
                         "per-token jitted steps (debug)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prefill", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.configs import get_config, smoke_config
    from repro.configs.base import ServeConfig
    from repro.models import build_model
    from repro.models.layers import unbox
    from repro.serve.engine import generate

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    cfg = cfg.with_(softmax_impl=args.softmax)
    model = build_model(cfg)
    params = unbox(model.init(jax.random.PRNGKey(args.seed)))

    key = jax.random.PRNGKey(args.seed + 1)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prefill), 0, cfg.vocab, jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (args.batch, cfg.frontend_len, cfg.frontend_dim))
    scfg = ServeConfig(batch=args.batch, prefill_len=args.prefill,
                       max_len=args.prefill + args.max_new + 1,
                       cache_dtype=args.cache_dtype,
                       temperature=args.temperature,
                       attn_mode=args.attn_mode,
                       decode_loop=args.decode_loop)
    out = generate(model, params, batch, scfg, max_new=args.max_new)
    for i, row in enumerate(out.tolist()):
        print(f"[{i}] {row}")


if __name__ == "__main__":
    main()
