"""Serving launcher CLI: lockstep batch decode or continuous batching.

  # uniform rectangular batch, one on-device lax.scan (PR 2 fast path)
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
      --batch 4 --prefill 16 --max-new 16 --softmax hyft16

  # continuous batching: ragged prompts, slot-pool KV cache, EOS early-exit
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
      --scheduler continuous --n-slots 4 --batch 8 --max-new 24 --eos-id 7

  # speculative decoding: n-gram self-drafting + one-call verify bursts
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
      --scheduler spec --draft-k 4 --n-slots 4 --batch 8 --max-new 24
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--softmax", default="hyft16")
    ap.add_argument("--attn-mode", default=None,
                    choices=["unfused", "chunked", "kernel"],
                    help="attention path; 'kernel' = split-K fused Pallas decode")
    ap.add_argument("--cache-dtype", default="float32",
                    help="KV cache storage: jnp dtype name or 'fp2fx8' "
                         "(int8 FP2FX raws + per-head scales)")
    ap.add_argument("--decode-loop", default="scan",
                    choices=["scan", "host"],
                    help="'scan' = one on-device lax.scan; 'host' = "
                         "per-token jitted steps (debug)")
    ap.add_argument("--scheduler", default="lockstep",
                    choices=["lockstep", "continuous", "spec"],
                    help="'continuous' = slot-pool continuous batching with "
                         "ragged prompts and EOS early-exit; 'lockstep' = "
                         "one rectangular batch (PR 2 fast path); 'spec' = "
                         "continuous admission + speculative decode bursts "
                         "(draft K tokens, verify in one model call)")
    ap.add_argument("--spec-mode", default="ngram",
                    choices=["ngram", "model"],
                    help="drafter for --scheduler spec: 'ngram' = "
                         "deterministic prompt-lookup self-drafting; "
                         "'model' = a small zoo model (--draft-model)")
    ap.add_argument("--draft-k", type=int, default=4,
                    help="draft tokens verified per slot per spec step")
    ap.add_argument("--ngram-max", type=int, default=3,
                    help="longest trailing n-gram the lookup drafter matches")
    ap.add_argument("--draft-model", default=None,
                    help="zoo arch for --spec-mode model (random init: a "
                         "demo drafter — acceptance floor is chance)")
    ap.add_argument("--n-slots", type=int, default=4,
                    help="slot-pool size for --scheduler continuous")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="stop token: a continuous-batching slot that emits "
                         "it is freed immediately")
    ap.add_argument("--decode-burst", type=int, default=8,
                    help="jitted decode steps between admission checks")
    ap.add_argument("--kv-layout", default="dense",
                    choices=["dense", "paged"],
                    help="'paged' = fixed-size KV pages from a global pool "
                         "with per-slot block tables (attention families; "
                         "decode appends pages on demand, exhaustion "
                         "preempts the latest-arrival slot)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page for --kv-layout paged")
    ap.add_argument("--n-pages", type=int, default=0,
                    help="usable pages in the pool (0 = auto: n_slots * "
                         "ceil(max_len / page_size))")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix-trie prompt prefix cache: admissions "
                         "sharing a cached prefix reuse its pages and skip "
                         "prefill for the cached tokens (paged only)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="max prompt tokens per prefill_chunk call (0 = "
                         "whole prompt in one call): long prompts split "
                         "into chunks interleaved with decode bursts, so "
                         "in-flight decode never stalls longer than one "
                         "chunk")
    ap.add_argument("--pack-prefill", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="pack every prefilling slot into one bucketed "
                         "chunk call (--no-pack-prefill = one prompt at a "
                         "time in arrival order, an ablation knob)")
    ap.add_argument("--audit", action="store_true",
                    help="recompute page-pool/radix-trie refcounts at every "
                         "admission/finish/preemption checkpoint and fail "
                         "loudly on drift (DESIGN.md §13)")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="bounded admission queue: arrivals past this many "
                         "waiting requests fail with reason 'queue_full' "
                         "(0 = unbounded)")
    ap.add_argument("--max-retries", type=int, default=32,
                    help="per-request requeue budget (preemptions + numeric "
                         "quarantines) before a structured "
                         "'retries_exhausted' failure")
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-request TTL in seconds: a request unfinished "
                         "at its deadline fails with reason 'deadline' and "
                         "frees its slot/pages within one burst")
    ap.add_argument("--batch", type=int, default=4,
                    help="lockstep batch size / continuous request count")
    ap.add_argument("--prefill", type=int, default=16,
                    help="prompt length (continuous: the maximum; prompts "
                         "are ragged in [prefill//2, prefill])")
    ap.add_argument("--max-new", type=int, default=16,
                    help="decode horizon (continuous: the maximum; horizons "
                         "are ragged in [max_new//2, max_new])")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0,
                    help="sampling: keep only the k highest logits "
                         "(0 = off; temperature > 0 only)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling: keep the smallest token set "
                         "with probability mass >= p (1.0 = off)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome trace-event JSON of the serve "
                         "(spans: admit/prefill/burst/spec-verify/compile/"
                         "preempt/evict/quarantine) to PATH — load it in "
                         "ui.perfetto.dev (DESIGN.md §15)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="append periodic metrics-registry JSONL snapshots "
                         "to PATH and print the end-of-run metrics report")
    ap.add_argument("--xla-profile", default=None, metavar="DIR",
                    help="jax.profiler programmatic capture around the "
                         "serve: xplane + trace.json.gz artifacts land "
                         "under DIR (view with tensorboard/xprof; "
                         "DESIGN.md §16)")
    ap.add_argument("--telemetry", action="store_true",
                    help="fold per-burst device-side numeric stats (softmax "
                         "exponent range, fp2fx8 scale histogram, int8 "
                         "saturation) into the burst outputs and print the "
                         "numerics summary (retraces the burst)")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config, smoke_config
    from repro.configs.base import ServeConfig
    from repro.models import build_model
    from repro.models.layers import unbox
    from repro.serve.engine import generate
    from repro.serve.scheduler import Request, SlotPoolEngine

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    cfg = cfg.with_(softmax_impl=args.softmax)
    model = build_model(cfg)
    root = jax.random.PRNGKey(args.seed)
    init_key, data_key, sample_key = jax.random.split(root, 3)
    params = unbox(model.init(init_key))

    scfg = ServeConfig(batch=args.batch, prefill_len=args.prefill,
                       max_len=args.prefill + args.max_new + 1,
                       cache_dtype=args.cache_dtype,
                       temperature=args.temperature,
                       top_k=args.top_k,
                       top_p=args.top_p,
                       attn_mode=args.attn_mode,
                       decode_loop=args.decode_loop,
                       scheduler=args.scheduler,
                       n_slots=args.n_slots,
                       eos_id=args.eos_id,
                       decode_burst=args.decode_burst,
                       kv_layout=args.kv_layout,
                       page_size=args.page_size,
                       n_pages=args.n_pages,
                       prefix_cache=args.prefix_cache,
                       prefill_chunk=args.prefill_chunk,
                       pack_prefill=args.pack_prefill,
                       spec_mode=args.spec_mode,
                       draft_k=args.draft_k,
                       ngram_max=args.ngram_max,
                       draft_model=args.draft_model,
                       audit=args.audit,
                       max_queue=args.max_queue,
                       max_retries=args.max_retries,
                       telemetry=args.telemetry)

    from repro.obs import Obs
    from repro.obs.profile import xla_profile
    obs = None
    if args.trace or args.metrics_out:
        obs = Obs.enabled(metrics_path=args.metrics_out)
        obs.tracer.enabled = args.trace is not None

    # the paged layout, prefix cache, spec decoding, and chunked prefill
    # live in the slot-pool scheduler, so those flags route through it even
    # under --scheduler lockstep (the rectangular generate path below is
    # dense-only, non-speculative, and would silently ignore them)
    if (args.scheduler in ("continuous", "spec")
            or args.kv_layout != "dense" or args.prefix_cache
            or args.prefill_chunk > 0):
        rng = np.random.default_rng(args.seed)
        reqs = []
        for rid in range(args.batch):
            plen = int(rng.integers(max(1, args.prefill // 2),
                                    args.prefill + 1))
            frames = None
            if cfg.family == "encdec":
                frames = np.asarray(jax.random.normal(
                    jax.random.fold_in(data_key, rid),
                    (cfg.frontend_len, cfg.frontend_dim)))
            reqs.append(Request(
                rid=rid,
                tokens=rng.integers(0, cfg.vocab, plen).astype(np.int32),
                max_new=int(rng.integers(max(1, args.max_new // 2),
                                         args.max_new + 1)),
                frames=frames, deadline=args.deadline))
        eng = SlotPoolEngine(model, params, scfg, key=sample_key, obs=obs)
        if obs is not None:
            # compile (and §16 cost-record) every executable up front, so
            # the trace separates compile spans from steady-state serving
            # and the cost book has rows for the roofline counter tracks
            eng.prewarm(max(len(r.tokens) for r in reqs))
        try:
            with xla_profile(args.xla_profile):
                done = eng.run(reqs)
        except KeyboardInterrupt:
            # graceful drain: in-flight slots free, every unfinished
            # request gets a partial Completion with cancelled=True —
            # no traceback, no lost work (DESIGN.md §13)
            done = eng.shutdown()
            print("\ninterrupted: drained "
                  f"{sum(1 for c in done.values() if c.cancelled)} "
                  "in-flight/queued requests as cancelled")
        for rid in sorted(done):
            c = done[rid]
            tag = ("" if c.ok else " CANCELLED" if c.cancelled
                   else f" FAILED({c.failure.reason})")
            print(f"[{rid}] prompt={c.prompt_len} new={len(c.tokens)}"
                  f"{tag} {c.tokens}")
        if args.scheduler == "spec":
            st = eng.stats
            acc = st["accepted_tokens"] / max(1, st["draft_tokens"])
            print(f"spec: steps={st['spec_steps']} "
                  f"drafted={st['draft_tokens']} "
                  f"accepted={st['accepted_tokens']} (rate {acc:.2f}) "
                  f"tokens/model-call="
                  f"{st['tokens_emitted'] / max(1, st['model_calls']):.2f}")
        if args.trace:
            eng.obs.tracer.write(args.trace)
            print(f"# wrote trace {args.trace} "
                  f"({len(eng.obs.tracer.events)} events; load in "
                  f"ui.perfetto.dev)")
        if args.metrics_out:
            print(eng.obs.metrics.report())
            print(f"# wrote metrics {args.metrics_out}")
        if args.telemetry:
            print(f"numerics: {eng.obs.numerics.summary()}")
        if args.xla_profile:
            print(f"# wrote xla profile under {args.xla_profile} "
                  "(xplane + trace.json.gz; view with xprof/tensorboard)")
        return

    batch = {"tokens": jax.random.randint(
        data_key, (args.batch, args.prefill), 0, cfg.vocab, jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            data_key, (args.batch, cfg.frontend_len, cfg.frontend_dim))
    # the sampling key derives from --seed (it used to be dropped, so
    # --temperature runs always sampled with the hardcoded PRNGKey(0))
    with xla_profile(args.xla_profile):
        out = generate(model, params, batch, scfg, max_new=args.max_new,
                       key=sample_key,
                       tracer=obs.tracer if obs is not None else None,
                       profile=obs.profile if obs is not None else None)
        jax.block_until_ready(out)
    for i, row in enumerate(out.tolist()):
        print(f"[{i}] {row}")
    if args.xla_profile:
        print(f"# wrote xla profile under {args.xla_profile} "
              "(xplane + trace.json.gz; view with xprof/tensorboard)")
    if args.trace:
        obs.tracer.write(args.trace)
        print(f"# wrote trace {args.trace} ({len(obs.tracer.events)} "
              f"events; load in ui.perfetto.dev)")
    if args.metrics_out:
        print("# --metrics-out: serve.* metrics live in the slot-pool "
              "scheduler; rerun with --scheduler continuous|spec")


if __name__ == "__main__":
    main()
