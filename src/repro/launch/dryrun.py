import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST precede any jax import: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell we build the *real* step (train_step with optimizer update,
prefill, or serve decode step), lower it with ShapeDtypeStruct inputs under
the production mesh, ``.compile()`` it, and record:
  * memory_analysis()  — per-device argument/output/temp bytes (fits check)
  * cost_analysis()    — per-device FLOPs / bytes accessed
  * collective bytes   — parsed from the post-SPMD HLO text
  * the three-term roofline + MODEL_FLOPS ratio (EXPERIMENTS.md §Roofline)

Results are cached as JSON under results/dryrun/ keyed by
(mesh, arch, shape, tag); re-runs skip finished cells unless --force.

Usage:
  python -m repro.launch.dryrun --arch all --shape all --mesh both
  python -m repro.launch.dryrun --arch mistral-nemo-12b --shape train_4k \
      --mesh single --tag chunked --attn-mode chunked
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro import optim
from repro.configs import ASSIGNED, SHAPES, cell_supported, get_config, input_specs
from repro.configs.base import TrainConfig
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.roofline import analysis
from repro.train import state as train_state
from repro.train.step import make_step_fn

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

# big archs get adafactor + fsdp + microbatching by default: anything else
# cannot fit optimizer state on a 16 GB/chip pod (recorded in EXPERIMENTS.md)
BIG = {"nemotron-4-340b": 16, "grok-1-314b": 32, "zamba2-7b": 64,
       "mistral-nemo-12b": 64, "phi3.5-moe-42b-a6.6b": 64}


@dataclasses.dataclass
class CellOpts:
    tag: str = "baseline"
    attn_mode: str | None = None     # None = arch default
    softmax: str | None = None
    remat: str = "full"
    optimizer: str | None = None
    microbatch: int | None = None
    fsdp: bool | None = None
    seq_shard: bool = False
    parallel_prefill: bool = False
    pad_vocab: int = 0          # pad vocab up to a multiple (shardability)
    donate: bool = True


def cell_path(mesh_kind, arch, shape, tag):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    return os.path.join(RESULTS_DIR, f"{mesh_kind}__{arch}__{shape}__{tag}.json")


def build_cfg(arch, opts: CellOpts):
    cfg = get_config(arch)
    kw = {}
    if opts.attn_mode:
        kw["attn_mode"] = opts.attn_mode
    if opts.softmax:
        kw["softmax_impl"] = opts.softmax
    if opts.parallel_prefill:
        kw["parallel_prefill"] = True
    if opts.pad_vocab:
        kw["vocab"] = -(-cfg.vocab // opts.pad_vocab) * opts.pad_vocab
    return cfg.with_(**kw) if kw else cfg


def lower_cell(arch: str, shape_name: str, mesh, opts: CellOpts):
    """Returns (lowered, chips, meta). Raises on sharding/lowering bugs."""
    shape = SHAPES[shape_name]
    cfg = build_cfg(arch, opts)
    model = build_model(cfg)
    chips = mesh.size
    fsdp = opts.fsdp if opts.fsdp is not None else arch in BIG
    rules = shd.default_rules(mesh, cfg, fsdp=fsdp)
    if opts.seq_shard:
        rules["seq"] = "model"
    specs = input_specs(cfg, shape)
    meta = dict(arch=arch, shape=shape_name, kind=shape.kind, tag=opts.tag,
                chips=chips, mesh=str(dict(mesh.shape)), fsdp=fsdp,
                opts=dataclasses.asdict(opts))

    params_abs = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    psh = shd.param_shardings(mesh, params_abs, rules)
    from repro.models.layers import unbox
    params_flat = unbox(params_abs)

    if shape.kind == "train":
        mb = opts.microbatch if opts.microbatch is not None else BIG.get(arch, 0)
        tcfg = TrainConfig(global_batch=shape.batch, seq_len=shape.seq,
                           microbatch=mb, remat=opts.remat)
        opt_name = opts.optimizer or (
            "adafactor" if arch in ("nemotron-4-340b", "grok-1-314b")
            else "adamw")
        ocfg = optim.OptConfig(name=opt_name)
        state_sh = train_state.state_shardings(mesh, model, ocfg, rules)
        state_abs = jax.eval_shape(
            lambda: train_state.init_state(model, ocfg, jax.random.PRNGKey(0)))
        batch_sh = shd.batch_shardings(mesh, specs, rules)
        step_fn = make_step_fn(model, tcfg, ocfg)
        meta.update(optimizer=opt_name, microbatch=mb,
                    tokens=shape.batch * shape.seq)
        with mesh:
            with shd.activation_rules(mesh, rules):
                jitted = jax.jit(step_fn, in_shardings=(state_sh, batch_sh),
                                 out_shardings=(state_sh, None),
                                 donate_argnums=(0,) if opts.donate else ())
                return jitted.lower(state_abs, specs), chips, meta

    if shape.kind == "prefill":
        cache_abs = jax.eval_shape(
            lambda: model.init_cache(params_flat, shape.batch, shape.seq,
                                     jnp.bfloat16))
        cache_sh = shd.cache_shardings(mesh, cache_abs, rules)
        batch_sh = shd.batch_shardings(mesh, specs, rules)
        meta.update(tokens=shape.batch * shape.seq)

        def prefill_fn(params, cache, batch):
            return model.prefill(params, cache, batch)
        with mesh:
            with shd.activation_rules(mesh, rules):
                jitted = jax.jit(prefill_fn,
                                 in_shardings=(psh, cache_sh, batch_sh),
                                 out_shardings=(None, cache_sh, None),
                                 donate_argnums=(1,) if opts.donate else ())
                return jitted.lower(params_flat, cache_abs, specs), chips, meta

    # decode: one new token against a seq_len cache
    cache_abs = jax.eval_shape(
        lambda: model.init_cache(params_flat, shape.batch, shape.seq,
                                 jnp.bfloat16))
    cache_sh = shd.cache_shardings(mesh, cache_abs, rules)
    tok_sh = shd.batch_shardings(mesh, specs, rules)
    pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
    meta.update(tokens=shape.batch)

    def serve_fn(params, cache, tokens1, pos):
        return model.decode_step(params, cache, tokens1, pos)
    with mesh:
        jitted = jax.jit(serve_fn,
                         in_shardings=(psh, cache_sh, tok_sh["tokens"], None),
                         out_shardings=(None, cache_sh),
                         donate_argnums=(1,) if opts.donate else ())
        return jitted.lower(params_flat, cache_abs, specs["tokens"],
                            pos_abs), chips, meta


def run_cell(arch, shape_name, mesh_kind, opts: CellOpts, force=False):
    path = cell_path(mesh_kind, arch, shape_name, opts.tag)
    if os.path.exists(path) and not force:
        return json.load(open(path))
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    ok, reason = cell_supported(cfg, shape)
    if not ok:
        result = dict(arch=arch, shape=shape_name, mesh=mesh_kind,
                      tag=opts.tag, status="skipped", reason=reason)
        json.dump(result, open(path, "w"), indent=1)
        return result

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        lowered, chips, meta = lower_cell(arch, shape_name, mesh, opts)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        tf = analysis.scan_trip_factor(
            build_cfg(arch, opts), meta["kind"], shape.seq, shape.batch,
            meta.get("microbatch", 0))
        roof = analysis.analyze(cost, hlo, chips, trip_factor=tf)
        mf = analysis.model_flops(build_cfg(arch, opts), meta["tokens"],
                                  "train" if meta["kind"] == "train"
                                  else "infer")
        result = dict(
            meta, status="ok", mesh_kind=mesh_kind, trip_factor=tf,
            raw_cost={k: cost.get(k, 0.0)
                      for k in ("flops", "bytes accessed", "transcendentals")},
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            memory=dict(
                argument_bytes=mem.argument_size_in_bytes,
                output_bytes=mem.output_size_in_bytes,
                temp_bytes=mem.temp_size_in_bytes,
                alias_bytes=mem.alias_size_in_bytes,
                peak_device_bytes=(mem.argument_size_in_bytes
                                   + mem.output_size_in_bytes
                                   + mem.temp_size_in_bytes
                                   - mem.alias_size_in_bytes),
            ),
            roofline=roof.to_dict(),
            model_flops=mf,
            useful_flops_ratio=(mf / roof.hlo_flops_global
                                if roof.hlo_flops_global else 0.0),
        )
    except Exception as e:  # sharding mismatch / OOM-at-compile are bugs
        result = dict(arch=arch, shape=shape_name, mesh=mesh_kind,
                      tag=opts.tag, status="error",
                      error=f"{type(e).__name__}: {e}",
                      tb=traceback.format_exc()[-2000:])
    json.dump(result, open(path, "w"), indent=1, default=str)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--attn-mode", default=None)
    ap.add_argument("--softmax", default=None)
    ap.add_argument("--remat", default="full")
    ap.add_argument("--optimizer", default=None)
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--fsdp", type=int, default=None)
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--parallel-prefill", action="store_true")
    ap.add_argument("--pad-vocab", type=int, default=0)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = ASSIGNED if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    opts = CellOpts(tag=args.tag, attn_mode=args.attn_mode,
                    softmax=args.softmax, remat=args.remat,
                    optimizer=args.optimizer, microbatch=args.microbatch,
                    fsdp=None if args.fsdp is None else bool(args.fsdp),
                    seq_shard=args.seq_shard,
                    parallel_prefill=args.parallel_prefill,
                    pad_vocab=args.pad_vocab)

    n_ok = n_skip = n_err = 0
    for mesh_kind in meshes:
        for arch in archs:
            for shape in shapes:
                r = run_cell(arch, shape, mesh_kind, opts, force=args.force)
                st = r["status"]
                n_ok += st == "ok"
                n_skip += st == "skipped"
                n_err += st == "error"
                if st == "ok":
                    roof = r["roofline"]
                    print(f"[{mesh_kind}] {arch:22s} {shape:12s} OK "
                          f"compile={r['compile_s']:6.1f}s "
                          f"peak={r['memory']['peak_device_bytes']/2**30:7.2f}GiB "
                          f"dom={roof['dominant']:10s} "
                          f"frac={roof['roofline_fraction']:.3f}", flush=True)
                elif st == "skipped":
                    print(f"[{mesh_kind}] {arch:22s} {shape:12s} SKIP "
                          f"({r['reason'][:60]})", flush=True)
                else:
                    print(f"[{mesh_kind}] {arch:22s} {shape:12s} ERROR "
                          f"{r['error'][:140]}", flush=True)
    print(f"done: ok={n_ok} skip={n_skip} err={n_err}")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
