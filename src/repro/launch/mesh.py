"""Production mesh construction (single-pod 16x16 and 2-pod 2x16x16).

Defined as functions so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    size = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < size:
        raise RuntimeError(
            f"need {size} devices, have {len(devices)}; the dry-run sets "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import")
    from jax.sharding import Mesh
    arr = np.asarray(devices[:size]).reshape(shape)
    return Mesh(arr, axes)


def make_host_mesh(shape=(1, 1), axes=("data", "model")):
    """Small mesh over whatever devices exist (tests / examples)."""
    import jax
    size = int(np.prod(shape))
    from jax.sharding import Mesh
    arr = np.asarray(jax.devices()[:size]).reshape(shape)
    return Mesh(arr, axes)
