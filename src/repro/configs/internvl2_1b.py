"""InternVL2-1B: ViT frontend (STUB patch embeddings) + 24L LM backbone.
[arXiv:2404.16821; hf].  frontend_len patches of frontend_dim arrive
precomputed per the assignment; a single projection maps them to d_model."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_head=64,
    d_ff=4864, vocab=151655, act="silu", mlp_gated=True, norm="rms",
    qkv_bias=True, rope_theta=1e6, max_seq=32768, tie_embeddings=True,
    frontend_dim=1024, frontend_len=256,
)
