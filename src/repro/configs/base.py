"""Config dataclasses: model / train / serve / mesh.

Every assigned architecture is a ``ModelConfig`` instance in its own module
(``repro/configs/<id>.py``); ``repro.configs.get_config(name)`` resolves it.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    d_head: int = 0
    d_ff: int = 0
    vocab: int = 0

    act: str = "silu"
    mlp_gated: bool = True
    norm: str = "rms"                # rms | ln | np_ln
    qkv_bias: bool = False
    rope_theta: Optional[float] = 10000.0
    max_seq: int = 131072
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    moe_top_k: int = 2
    capacity_factor: float = 1.25
    moe_group: int = 512             # dispatch group size (memory bound)

    # SSM (Mamba2 / hybrid)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 64
    attn_every: int = 0              # hybrid: shared attn after every N ssm blocks

    # enc-dec / stub frontends
    enc_layers: int = 0
    frontend_dim: int = 0            # stub frame/patch embedding width
    frontend_len: int = 0            # stub sequence length (patches / frames)

    # the paper's technique + execution knobs
    softmax_impl: str = "hyft32"
    attn_mode: str = "unfused"       # unfused | chunked | kernel
    attn_chunk: int = 512

    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    # long-context capability marker (sub-quadratic decode path exists)
    subquadratic: bool = False
    # prefill strategy: False = naive token-scan (baseline), True = one-pass
    # chunked-SSD / teacher-forced cache fill (§Perf lever)
    parallel_prefill: bool = False

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    global_batch: int = 256
    seq_len: int = 4096
    microbatch: int = 0              # 0 = no gradient accumulation
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    optimizer: str = "adamw"         # adamw | sgd | adafactor
    remat: str = "full"              # none | full | dots
    z_loss: float = 1e-4
    moe_aux_weight: float = 0.01
    grad_compression: str = "none"   # none | int8
    master_dtype: str = "float32"
    seed: int = 0
    checkpoint_every: int = 100
    keep_checkpoints: int = 3
    # attention-mode override (None = use the model config's attn_mode);
    # "kernel" trains through the fused Pallas fwd+bwd kernels
    attn_mode: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    batch: int = 8
    prefill_len: int = 128
    max_len: int = 256
    # KV-cache storage: any jnp dtype name, or "fp2fx8" = int8 FP2FX raws +
    # per-(head, position) fp32 scale (dequant fused into the decode kernel)
    cache_dtype: str = "bfloat16"
    seq_parallel: bool = False       # sequence-parallel decode attention
    temperature: float = 0.0
    # sampling filters (temperature > 0 only): top_k = 0 disables, top_p =
    # 1.0 disables; both applied to the temperature-scaled logits (top-k
    # first, then the nucleus) in ``repro.serve.engine._sample``
    top_k: int = 0
    top_p: float = 1.0
    # attention-mode override (None = use the model config's attn_mode);
    # "kernel" keeps masked decode on the fused (split-K) Pallas kernel
    attn_mode: Optional[str] = None
    # decode loop: "scan" = one jitted on-device lax.scan (donated cache,
    # sampling in the loop); "host" = per-token jitted steps (debug fallback)
    decode_loop: str = "scan"
    # --- continuous batching (repro/serve/scheduler.py) ---
    # per-sequence stop token: a slot that emits it is freed on device
    # (None = run every request to its own max_new)
    eos_id: Optional[int] = None
    # slot-pool size: the fixed batch dimension of the serving KV cache
    n_slots: int = 8
    # "continuous" = admit queued requests into freed slots mid-decode;
    # "lockstep" = drain the whole pool before admitting the next group
    # (the PR 2-style rectangular baseline, generalized to ragged prompts);
    # "spec" = continuous admission + speculative decode bursts
    # (repro/serve/spec.py): draft K tokens per slot, verify them in ONE
    # prefill-shaped model call, keep the longest accepted prefix
    scheduler: str = "lockstep"
    # jitted masked decode steps per burst between host admission checks
    decode_burst: int = 8
    # --- paged KV cache + prefix caching (repro/serve/kvpool.py, §10) ---
    # "dense" = one (max_len,) KV stripe per slot; "paged" = fixed-size
    # pages from a global pool with per-slot block tables (attention
    # families only; decode appends pages on demand, exhaustion preempts)
    kv_layout: str = "dense"
    # tokens per KV page (paged layout)
    page_size: int = 16
    # usable pages in the pool (0 = auto: n_slots * ceil(max_len/page_size))
    n_pages: int = 0
    # radix-trie prefix cache: admissions sharing a cached prompt prefix
    # reuse its pages (refcounted, copy-on-write by page granularity) and
    # skip prefill for the cached tokens (paged layout only)
    prefix_cache: bool = False
    # --- chunked + packed prefill (DESIGN.md §12) ---
    # max prompt tokens written per prefill_chunk call (0 = whole prompt in
    # one call): long prompts split into chunks scheduled BETWEEN decode
    # bursts, so in-flight decode never stalls longer than one chunk — and
    # prompts longer than any single compiled bucket become servable
    prefill_chunk: int = 0
    # pack every prefilling slot into one bucketed chunk call (per-row
    # start/lengths keep rows independent); False = one prompt at a time
    # in arrival order (an ablation/debugging knob)
    pack_prefill: bool = True
    # --- speculative decoding (repro/serve/spec.py, DESIGN.md §11) ---
    # drafter for scheduler="spec": "ngram" = deterministic prompt-lookup
    # self-drafting (no second model — greedy outputs provably unchanged);
    # "model" = a small zoo model sharing the slot pool (inject it via
    # SlotPoolEngine(draft=(model, params)))
    spec_mode: str = "ngram"
    # draft tokens verified per slot per spec step (the verify chunk is
    # draft_k + 1 lanes: [last_token, draft_1..draft_k])
    draft_k: int = 4
    # longest trailing n-gram the prompt-lookup drafter matches
    ngram_max: int = 3
    # zoo arch name for spec_mode="model" launched from the CLI (random
    # init unless params are injected — a demo drafter, not a good one)
    draft_model: Optional[str] = None
    # --- serving robustness (repro/serve/chaos.py, DESIGN.md §13) ---
    # recompute page-pool/radix-trie refcounts from live slots + trie edges
    # and cross-check the free list after every admission / finish /
    # preemption / quarantine checkpoint (kvpool.AuditError on drift) —
    # host-only, never part of a jit compilation key
    audit: bool = False
    # bounded admission queue: an ARRIVAL that would push the waiting queue
    # past this many requests is rejected with a structured "queue_full"
    # failure instead of waiting unboundedly (0 = unbounded; requeues from
    # preemption/quarantine are exempt — they already held an admission)
    max_queue: int = 0
    # per-request requeue budget (preemptions + numeric quarantines): one
    # more requeue past this surfaces a "retries_exhausted" failure with
    # the partial tokens instead of looping forever under pressure
    max_retries: int = 32
    # --- hybrid-format telemetry (repro/obs/numerics.py, DESIGN.md §15) ---
    # fold per-burst device-side numeric stats (softmax-input exponent
    # range pre/post max-subtraction; fp2fx8 scale histogram + int8
    # saturation from the final burst cache) into the burst/spec outputs;
    # part of the burst compile key — flipping it retraces
    telemetry: bool = False


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    shape: tuple = (16, 16)
    axes: tuple = ("data", "model")

    @property
    def multi_pod(self) -> bool:
        return "pod" in self.axes
