"""Phi-3.5-MoE (42B total / 6.6B active): 16 experts top-2, GQA.
[hf:microsoft/Phi-3.5-MoE-instruct; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=6400, vocab=32064, act="silu", mlp_gated=True, norm="ln",
    rope_theta=10000.0, max_seq=131072, param_dtype="bfloat16",
    n_experts=16, moe_top_k=2,
)
