"""Qwen2-1.5B: GQA (kv=2), QKV bias, tied embeddings. [arXiv:2407.10671; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b", family="dense",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, d_head=128,
    d_ff=8960, vocab=151936, act="silu", mlp_gated=True, norm="rms",
    qkv_bias=True, rope_theta=1e6, max_seq=131072, tie_embeddings=True,
)
