"""Assigned input-shape sets + ShapeDtypeStruct input specs per (arch, shape).

Shapes (per assignment):
  train_4k     seq 4096,   global_batch 256   -> train_step
  prefill_32k  seq 32768,  global_batch 32    -> prefill (serve)
  decode_32k   seq 32768,  global_batch 128   -> serve_step (1 new token,
                                                 KV/state cache of seq_len)
  long_500k    seq 524288, global_batch 1     -> serve_step; ONLY for
               sub-quadratic archs (ssm/hybrid), skipped + recorded for the
               eight pure full-attention archs (DESIGN.md §5).

``input_specs`` returns weak-type-correct ShapeDtypeStructs (no allocation).
Decode cache specs are derived separately via ``jax.eval_shape`` of the
model's ``init_cache`` in the dry-run driver.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

I32 = jnp.int32
F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str       # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def cell_supported(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Is this (arch x shape) cell runnable? Returns (ok, reason-if-not)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("pure full-attention arch: 500k-token decode requires "
                       "a sub-quadratic path (DESIGN.md §5)")
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Model-input stand-ins for one cell (no device allocation)."""
    B, S = shape.batch, shape.seq
    if shape.kind == "train":
        if cfg.family == "encdec":
            return {"frames": _sds((B, cfg.frontend_len, cfg.frontend_dim), F32),
                    "tokens": _sds((B, S), I32),
                    "targets": _sds((B, S), I32),
                    "mask": _sds((B, S), F32)}
        if cfg.family == "vlm":
            s_txt = S - cfg.frontend_len
            return {"embeds": _sds((B, cfg.frontend_len, cfg.frontend_dim), F32),
                    "tokens": _sds((B, s_txt), I32),
                    "targets": _sds((B, s_txt), I32),
                    "mask": _sds((B, s_txt), F32)}
        return {"tokens": _sds((B, S), I32),
                "targets": _sds((B, S), I32),
                "mask": _sds((B, S), F32)}
    if shape.kind == "prefill":
        if cfg.family == "encdec":
            return {"frames": _sds((B, cfg.frontend_len, cfg.frontend_dim), F32),
                    "tokens": _sds((B, S), I32)}
        if cfg.family == "vlm":
            return {"embeds": _sds((B, cfg.frontend_len, cfg.frontend_dim), F32),
                    "tokens": _sds((B, S - cfg.frontend_len), I32)}
        return {"tokens": _sds((B, S), I32)}
    # decode: one new token against a cache of length S
    return {"tokens": _sds((B, 1), I32)}


def concrete_batch(cfg: ModelConfig, shape: ShapeSpec, key=None) -> dict:
    """Tiny concrete batch matching input_specs (for smoke tests)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    specs = input_specs(cfg, shape)
    out = {}
    for k, s in specs.items():
        if s.dtype == I32:
            key, sub = jax.random.split(key)
            out[k] = jax.random.randint(sub, s.shape, 0, max(cfg.vocab, 2), I32)
        else:
            key, sub = jax.random.split(key)
            out[k] = jax.random.normal(sub, s.shape, F32)
    if "mask" in out:
        out["mask"] = jnp.ones_like(out["mask"])
    return out
