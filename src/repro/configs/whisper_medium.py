"""Whisper-medium: enc-dec, conv frontend STUB (precomputed frame embeds).
[arXiv:2212.04356].  24 encoder + 24 decoder layers; rope stands in for the
learned absolute positions (DESIGN.md §7)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_head=64, d_ff=4096, vocab=51865, act="gelu", mlp_gated=False,
    norm="ln", rope_theta=10000.0, max_seq=32768, tie_embeddings=True,
    frontend_dim=80, frontend_len=1500,
)
