"""Config registry: ``get_config(arch_id)`` + smoke-test reduction."""
from __future__ import annotations

import importlib

from repro.configs.base import MeshConfig, ModelConfig, ServeConfig, TrainConfig  # noqa: F401
from repro.configs.shapes import SHAPES, ShapeSpec, cell_supported, input_specs  # noqa: F401

ARCHS = {
    "mistral-nemo-12b": "mistral_nemo_12b",
    "nemotron-4-340b": "nemotron_4_340b",
    "olmo-1b": "olmo_1b",
    "qwen2-1.5b": "qwen2_1_5b",
    "internvl2-1b": "internvl2_1b",
    "mamba2-370m": "mamba2_370m",
    "zamba2-7b": "zamba2_7b",
    "grok-1-314b": "grok_1_314b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b",
    "whisper-medium": "whisper_medium",
    "bert-base": "bert_base",
}

ASSIGNED = [a for a in ARCHS if a != "bert-base"]


def get_config(name: str) -> ModelConfig:
    try:
        mod = importlib.import_module(f"repro.configs.{ARCHS[name]}")
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return mod.CONFIG


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config: tiny widths, few layers/experts — runs a
    real fwd/train step on CPU (the FULL config is dry-run-only)."""
    heads = min(cfg.n_heads, 4) if cfg.n_heads else 0
    kv = min(cfg.n_kv_heads, heads) if cfg.n_kv_heads else 0
    if kv and heads % kv:
        kv = 1
    kw = dict(
        n_layers=min(cfg.n_layers, 4 if cfg.family == "hybrid" else 2),
        d_model=64, n_heads=heads, n_kv_heads=kv,
        d_head=16 if heads else 0,
        d_ff=128 if cfg.d_ff else 0,
        vocab=min(cfg.vocab, 512),
        max_seq=256,
    )
    if cfg.n_experts:
        kw["n_experts"] = min(cfg.n_experts, 4)
        kw["moe_group"] = 16
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=8)
    if cfg.attn_every:
        kw["attn_every"] = 2
    if cfg.enc_layers:
        kw["enc_layers"] = 2
    if cfg.frontend_dim:
        kw.update(frontend_dim=16, frontend_len=8)
    kw["param_dtype"] = "float32"
    return cfg.with_(**kw)
