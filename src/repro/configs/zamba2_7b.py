"""Zamba2-7B: Mamba2 backbone + SHARED attention block every 6 layers.
[arXiv:2411.15242].  The shared block is one parameter set reused at every
invocation (the paper adds per-invocation LoRA deltas; omitted — noted in
DESIGN.md §7)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, d_head=112,
    d_ff=14336, vocab=32000, act="silu", mlp_gated=True, norm="rms",
    rope_theta=10000.0, max_seq=1048576,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_conv=4, ssm_chunk=64,
    attn_every=6, subquadratic=True, param_dtype="bfloat16",
)
