"""BERT-base proxy — the paper's own evaluation model (Tables 1-2).
Used by the accuracy benchmarks (bidirectional forward + pooling head)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="bert-base", family="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_head=64,
    d_ff=3072, vocab=30522, act="gelu", mlp_gated=False, norm="ln",
    rope_theta=10000.0, max_seq=512, tie_embeddings=True,
)
