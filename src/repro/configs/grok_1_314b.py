"""Grok-1 (314B MoE): 8 experts top-2, GQA. [hf:xai-org/grok-1]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
    d_ff=32768, vocab=131072, act="gelu", mlp_gated=True, norm="rms",
    rope_theta=10000.0, max_seq=8192, param_dtype="bfloat16",
    n_experts=8, moe_top_k=2,
)
