"""Mamba2-370M: pure SSD (attention/softmax-free). [arXiv:2405.21060]
The paper's softmax technique is INAPPLICABLE here (DESIGN.md §5); the arch
exercises sharding/remat/long-context decode."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, d_ff=0, vocab=50280, norm="rms",
    rope_theta=None, max_seq=1048576, tie_embeddings=True,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_conv=4, ssm_chunk=64,
    subquadratic=True,
)
