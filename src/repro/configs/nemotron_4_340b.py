"""Nemotron-4-340B: GQA + squared-ReLU (ungated). [arXiv:2402.16819]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b", family="dense",
    n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8, d_head=192,
    d_ff=73728, vocab=256000, act="squared_relu", mlp_gated=False, norm="ln",
    rope_theta=10000.0, max_seq=4096, param_dtype="bfloat16",
)
