"""OLMo-1B: non-parametric LayerNorm, tied embeddings. [arXiv:2402.00838; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
    d_ff=8192, vocab=50304, act="silu", mlp_gated=True, norm="np_ln",
    rope_theta=10000.0, max_seq=2048, tie_embeddings=True,
)
