"""Mistral-Nemo-Base-2407 (12B). [hf:mistralai/Mistral-Nemo-Base-2407; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=14336, vocab=131072, act="silu", mlp_gated=True, norm="rms",
    rope_theta=1e6, max_seq=131072, param_dtype="bfloat16",
)
