"""Fault tolerance: restart manager, straggler monitor, elastic re-meshing.

Large-fleet posture (DESIGN.md §4):
  * RestartManager — supervises the train loop; on failure it reloads the
    latest *atomic* checkpoint and retries (bounded).  On a real cluster the
    same manager runs under the cluster scheduler; node loss surfaces as an
    exception here exactly as a collective timeout does there.
  * StragglerMonitor — per-step wall-time EMA + MAD outlier detection; on a
    fleet this feeds hot-spare swap / within-step backup execution, here it
    logs and counts (tested with injected delays).
  * elastic_remesh — rebuilds a (data, model) mesh from the devices still
    alive (data axis shrinks, model axis is sacred: TP groups must stay
    whole), and checkpoints re-shard on restore (`checkpointer.restore`
    takes new shardings) — that is elastic scaling.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax

from repro.checkpoint import checkpointer


@dataclasses.dataclass
class StragglerMonitor:
    ema: float = 0.0
    beta: float = 0.9
    threshold: float = 3.0
    warm: int = 5
    seen: int = 0
    flagged: int = 0

    def observe(self, dt: float) -> bool:
        self.seen += 1
        if self.seen <= self.warm:
            self.ema = dt if self.ema == 0 else (self.beta * self.ema
                                                 + (1 - self.beta) * dt)
            return False
        is_straggler = dt > self.threshold * max(self.ema, 1e-9)
        if is_straggler:
            self.flagged += 1
        else:  # don't pollute the EMA with outliers
            self.ema = self.beta * self.ema + (1 - self.beta) * dt
        return is_straggler


def elastic_remesh(model_size: int, axes=("data", "model"),
                   devices=None):
    """Mesh from whatever devices are alive; data axis absorbs the loss."""
    devices = devices if devices is not None else jax.devices()
    n = len(devices)
    if n % model_size:
        usable = (n // model_size) * model_size
        devices = devices[:usable]
        n = usable
    if n == 0:
        raise RuntimeError("not enough devices to keep a model-parallel group")
    import numpy as np
    arr = np.array(devices).reshape(n // model_size, model_size)
    from jax.sharding import Mesh
    return Mesh(arr, axes)


@dataclasses.dataclass
class RestartManager:
    ckpt_dir: str
    max_restarts: int = 3
    on_restart: Optional[Callable[[int], None]] = None

    def run(self, body: Callable[[int], int]) -> int:
        """``body(start_step) -> final_step`` — rerun from the latest
        checkpoint on failure."""
        restarts = 0
        while True:
            start = checkpointer.latest_step(self.ckpt_dir)
            start = 0 if start is None else start
            try:
                return body(start)
            except (KeyboardInterrupt, SystemExit):
                raise
            except Exception as e:  # noqa: BLE001 — any node failure mode
                restarts += 1
                if restarts > self.max_restarts:
                    raise RuntimeError(
                        f"exceeded {self.max_restarts} restarts") from e
                if self.on_restart:
                    self.on_restart(restarts)
                time.sleep(0.01)
