"""Logical-axis -> mesh-axis sharding rules (DP / FSDP / TP / EP / SP).

Params carry *logical* axis names (``repro.models.layers.Param``); a rules
table maps them to mesh axes.  Rules are per-arch-overridable — this is the
primary §Perf hillclimb lever (changing one rule re-shards the whole model).

Conventions:
  batch       -> (pod, data)      activations' batch dim
  heads/mlp/  -> model            tensor parallelism
  vocab/experts
  embed       -> fsdp axes for big archs (ZeRO-3), replicated for small
  kv_heads    -> model only when divisible, else replicated (GQA kv<16)
"""
from __future__ import annotations

import contextlib
from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.layers import boxed_axes, is_param

# ---------------------------------------------------------------------------
# activation-sharding context (Megatron-style sequence parallelism lever)
# ---------------------------------------------------------------------------

_ACT_CTX: list = []


@contextlib.contextmanager
def activation_rules(mesh, rules):
    """While active, ``constrain_acts`` pins the residual stream's sharding
    (batch over DP axes; seq over ``model`` iff rules["seq"] says so)."""
    _ACT_CTX.append((mesh, rules))
    try:
        yield
    finally:
        _ACT_CTX.pop()


def constrain_acts(x):
    """Apply the (batch, seq, embed) activation constraint if a context is
    active and the shape divides; no-op otherwise."""
    if not _ACT_CTX or x.ndim != 3:
        return x
    mesh, rules = _ACT_CTX[-1]
    spec = spec_for_axes(("batch", "seq", "embed"), rules)
    spec = _divisible(spec, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def default_rules(mesh, cfg=None, fsdp: bool = False) -> dict[str, Any]:
    """Build the logical->mesh table for a given mesh (axes subset of
    ("pod","data","model"))."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    model = "model" if "model" in mesh.axis_names else None
    msize = mesh.shape.get("model", 1)
    rules: dict[str, Any] = {
        "batch": dp if len(dp) > 1 else (dp[0] if dp else None),
        "seq": None,
        "embed": dp if fsdp else None,     # ZeRO-3 over the data axes
        "heads": model,
        "kv_heads": None,                  # GQA: kv heads rarely divide 16
        "head_dim": None,
        "mlp": model,
        "vocab": model,
        "experts": model,
        "experts_dim": None,
        "layers": None,
        None: None,
    }
    if cfg is not None:
        if cfg.n_heads and model and cfg.n_heads % msize:
            rules["heads"] = None
        if cfg.n_kv_heads and model and cfg.n_kv_heads % msize == 0:
            rules["kv_heads"] = model
        if cfg.n_experts and model and cfg.n_experts % msize:
            # few experts: shard experts over what divides, mlp picks up TP
            rules["experts"] = None
        if cfg.d_ff and model and cfg.d_ff % msize:
            rules["mlp"] = None
        if cfg.vocab and model and cfg.vocab % msize:
            rules["vocab"] = None
    return rules


def spec_for_axes(axes, rules, shape=None) -> P:
    """Logical axes tuple -> PartitionSpec, dropping non-divisible entries."""
    if axes is None:
        return P()
    entries = []
    used = set()
    for i, a in enumerate(axes):
        r = rules.get(a, None)
        # one mesh axis may appear only once in a spec
        flat = tuple(r) if isinstance(r, tuple) else ((r,) if r else ())
        flat = tuple(x for x in flat if x not in used)
        used.update(flat)
        r = flat if len(flat) > 1 else (flat[0] if flat else None)
        entries.append(r)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def _divisible(spec: P, shape, mesh) -> P:
    """Drop spec entries whose mesh-axis product doesn't divide the dim."""
    entries = []
    for i, e in enumerate(spec):
        if e is None:
            entries.append(None)
            continue
        axes = e if isinstance(e, tuple) else (e,)
        n = int(np.prod([mesh.shape[a] for a in axes]))
        entries.append(e if shape[i] % n == 0 else None)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def param_shardings(mesh, boxed_tree, rules):
    """Boxed param pytree -> NamedSharding pytree (for jit in/out_shardings).

    ``boxed_tree`` may hold Param(ShapeDtypeStruct) from ``jax.eval_shape``;
    the leading scan ``layers`` axis is detected by rank mismatch and left
    unsharded.
    """
    def one(p):
        if not is_param(p):
            return NamedSharding(mesh, P())
        axes = p.axes
        shape = p.value.shape
        if len(axes) == len(shape) - 1:       # stacked scan layer axis
            axes = ("layers",) + tuple(axes)
        elif len(axes) == len(shape) - 2:     # nested stacking (hybrid groups)
            axes = ("layers", "layers") + tuple(axes)
        spec = spec_for_axes(axes, rules)
        spec = _divisible(spec, shape, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree.map(one, boxed_tree, is_leaf=is_param)


def batch_shardings(mesh, batch_specs, rules):
    """Input batch pytree -> NamedSharding with batch dim over DP axes."""
    bspec = spec_for_axes(("batch",), rules)

    def one(x):
        spec = P(*(tuple(bspec)[0],)) if len(x.shape) >= 1 else P()
        return NamedSharding(mesh, _divisible(spec, x.shape, mesh))

    return jax.tree.map(one, batch_specs)


def cache_shardings(mesh, cache_specs, rules, seq_axis_map=None):
    """KV/state cache sharding for decode.

    Attention KV caches (B, Hkv, S, D) [stacked (L, ...)]: batch over DP; the
    sequence axis over ``model`` (sequence parallelism — the distributed Hyft
    tree consumes it).  SSM states (B, H, P, N): heads over model.
    """
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp = dp if len(dp) > 1 else (dp[0] if dp else None)
    model = "model" if "model" in mesh.axis_names else None

    def one(path, x):
        keys = {getattr(k, "key", getattr(k, "name", None)) for k in path}
        shape, r = x.shape, len(x.shape)
        if keys & {"k", "v"}:        # attention KV: (L,)B,Hkv,S,D — SP on seq
            spec = [None] * r
            spec[r - 4], spec[r - 2] = dp, model
        elif "ssm" in keys:          # SSD state: (L,)B,H,P,N — TP on heads
            spec = [None] * r
            spec[r - 4], spec[r - 3] = dp, model
        elif "conv" in keys:         # conv window: (L,)B,K,C — TP on channels
            spec = [None] * r
            spec[r - 3], spec[r - 1] = dp, model
        elif "memory" in keys:       # encoder memory: B,T,D
            spec = [dp] + [None] * (r - 1)
        else:
            spec = ([dp] + [None] * (r - 1)) if r else []
        return NamedSharding(mesh, _divisible(P(*spec), shape, mesh))

    return jax.tree_util.tree_map_with_path(one, cache_specs)
