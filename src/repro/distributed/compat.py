"""Version-portable imports/constructors for fast-moving JAX APIs.

One blessed spelling for src *and* tests — when JAX moves or reshapes an
API, this is the only file that chases it.
"""
from __future__ import annotations

try:  # newer JAX exports shard_map at the top level
    from jax import shard_map  # type: ignore[attr-defined]
except ImportError:  # the long-standing experimental home
    from jax.experimental.shard_map import shard_map  # noqa: F401


def abstract_mesh(shape, axis_names):
    """Construct ``jax.sharding.AbstractMesh`` across JAX versions.

    Newer JAX takes one ``((name, size), ...)`` shape tuple; older releases
    took ``(shape, axis_names)``.  Spec math on an AbstractMesh needs no
    device allocation, so production geometries (16x16, 2x16x16) are
    testable on a single CPU.
    """
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(zip(axis_names, shape)))
    except TypeError:
        return AbstractMesh(tuple(shape), tuple(axis_names))
