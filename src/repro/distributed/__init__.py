from repro.distributed import fault_tolerance, sharding  # noqa: F401
from repro.distributed.compat import abstract_mesh, shard_map  # noqa: F401
