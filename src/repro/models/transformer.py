"""Decoder-only LM stack: dense / MoE / SSM / hybrid, scan-over-layers.

One parameter pytree per *layer kind*, stacked on a leading ``layers`` axis
and consumed by ``lax.scan`` — the HLO stays compact at any depth (96-layer
nemotron lowers as fast as 2 layers), which is what makes the 40-cell
multi-pod dry-run tractable.  Hybrid (zamba2) scans Mamba2 blocks and applies
the *shared* attention block (single param set, closure-captured) via
``lax.cond`` on a per-layer flag.

Remat: each scanned block body is wrapped in ``jax.checkpoint`` with a
configurable policy ("full" saves only the residual stream).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain_acts
from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import embed_init, embed_lookup, make_norm, param, unembed

F32 = jnp.float32


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _norm_pair(key, cfg):
    p1, f = make_norm(cfg.norm, cfg.d_model, cfg.pdtype)
    p2, _ = make_norm(cfg.norm, cfg.d_model, cfg.pdtype)
    return {"pre_attn": p1, "pre_mlp": p2}, f


def _block_init(key, cfg):
    """One transformer block (attention + mlp/moe)."""
    ks = jax.random.split(key, 3)
    norms, _ = _norm_pair(ks[0], cfg)
    p = {"norms": norms, "attn": attn.attn_init(ks[1], cfg, cfg.pdtype)}
    if cfg.family == "moe":
        p["moe"] = moe_mod.moe_init(ks[2], cfg, cfg.pdtype)
    else:
        p["mlp"] = mlp_mod.mlp_init(ks[2], cfg, cfg.pdtype)
    return p


def _block_apply(p, x, cfg, positions, *, causal=True, decode_cache=None,
                 pos_offset=0, kv_len_mask=None, write_mask=None,
                 paged_bt=None):
    """Returns (x, aux, new_cache).

    ``pos_offset`` may be a (B,) vector (ragged decode: each row writes its
    KV at its own position) and ``write_mask`` (B,) gates the cache write per
    row — the slot-pool contract (finished slots stop mutating their cache).
    ``paged_bt`` (B, nb) switches the cache to the paged layout: the write
    scatters through the block table (masked rows redirected to the null
    page) and attention gathers pages (DESIGN.md §10).
    """
    _, norm_fn = make_norm(cfg.norm, cfg.d_model, cfg.pdtype)
    h = norm_fn(p["norms"]["pre_attn"], x)
    q, k, v = attn.qkv_proj(p["attn"], h, h, cfg, positions, positions)
    if decode_cache is not None and paged_bt is not None:
        pos_b = jnp.broadcast_to(jnp.asarray(pos_offset, jnp.int32),
                                 (x.shape[0],))
        cache = attn.cache_update_paged(decode_cache, k, v, pos_b, paged_bt,
                                        write_mask)
        o = attn.decode_attention_paged(q, cache, paged_bt, cfg,
                                        kv_len_mask=kv_len_mask)
    elif decode_cache is not None:
        if jnp.ndim(pos_offset) >= 1 or write_mask is not None:
            pos_b = jnp.broadcast_to(jnp.asarray(pos_offset, jnp.int32),
                                     (x.shape[0],))
            cache = attn.cache_update_ragged(decode_cache, k, v, pos_b,
                                             write_mask)
        else:
            cache = attn.cache_update(decode_cache, k, v, pos_offset)
        # masked decode goes through the decode dispatch: with
        # attn_mode="kernel" this is the split-K fused Pallas path, reading
        # fp2fx8 cache raws directly when the cache is quantized
        o = attn.decode_attention(q, cache, cfg, kv_len_mask=kv_len_mask)
    else:
        cache = None
        o = attn.attention_fwd(q, k, v, cfg, causal=causal)
    x = x + attn.out_proj(p["attn"], o.astype(x.dtype))
    h = norm_fn(p["norms"]["pre_mlp"], x)
    aux = jnp.zeros((), F32)
    if "moe" in p:
        y, aux = moe_mod.moe_apply(p["moe"], h, cfg)
    else:
        y = mlp_mod.mlp_apply(p["mlp"], h, cfg)
    return x + y.astype(x.dtype), aux, cache


def _mamba_block_init(key, cfg):
    ks = jax.random.split(key, 2)
    norm_p, _ = make_norm(cfg.norm, cfg.d_model, cfg.pdtype)
    return {"norm": norm_p, "ssm": ssm_mod.ssm_init(ks[1], cfg, cfg.pdtype)}


def _mamba_block_apply(p, x, cfg, *, decode_cache=None, write_mask=None):
    _, norm_fn = make_norm(cfg.norm, cfg.d_model, cfg.pdtype)
    h = norm_fn(p["norm"], x)
    if decode_cache is not None:
        y, cache = ssm_mod.ssm_decode(p["ssm"], h, decode_cache, cfg)
        if write_mask is not None:  # inactive rows keep their old state
            cache = jax.tree.map(
                lambda n, o: jnp.where(
                    write_mask.reshape((-1,) + (1,) * (n.ndim - 1)),
                    n, o.astype(n.dtype)),
                cache, decode_cache)
        return x + y, cache
    return x + ssm_mod.ssm_train(p["ssm"], h, cfg), None


# --------------------------------------------------------------------------
# model init
# --------------------------------------------------------------------------


def init(key, cfg) -> dict[str, Any]:
    ks = jax.random.split(key, 8)
    p: dict[str, Any] = {"embed": embed_init(ks[0], cfg.vocab, cfg.d_model,
                                             cfg.pdtype)}
    fnorm, _ = make_norm(cfg.norm, cfg.d_model, cfg.pdtype)
    p["final_norm"] = fnorm
    if not cfg.tie_embeddings:
        p["unembed"] = embed_init(ks[1], cfg.vocab, cfg.d_model, cfg.pdtype)
    if cfg.family in ("dense", "moe", "vlm"):
        lk = jax.random.split(ks[2], cfg.n_layers)
        p["blocks"] = _stack([_block_init(k, cfg) for k in lk])
    elif cfg.family == "ssm":
        lk = jax.random.split(ks[2], cfg.n_layers)
        p["blocks"] = _stack([_mamba_block_init(k, cfg) for k in lk])
    elif cfg.family == "hybrid":
        lk = jax.random.split(ks[2], cfg.n_layers)
        p["blocks"] = _stack([_mamba_block_init(k, cfg) for k in lk])
        p["shared_attn"] = _block_init(ks[3], cfg)  # single shared block
    else:
        raise ValueError(cfg.family)
    if cfg.family == "vlm":
        p["frontend_proj"] = {
            "w": param(ks[4], (cfg.frontend_dim, cfg.d_model),
                       (None, "embed"), cfg.pdtype)}
    return p


def _hybrid_attn_flags(cfg) -> jnp.ndarray:
    """True after every ``attn_every``-th ssm block (zamba2 pattern)."""
    idx = jnp.arange(cfg.n_layers)
    return (idx % cfg.attn_every) == (cfg.attn_every - 1)


def hybrid_n_invocations(cfg) -> int:
    return cfg.n_layers // cfg.attn_every


def _hybrid_inv_idx(cfg) -> jnp.ndarray:
    """Invocation index per layer (valid where the flag is True).

    The shared block shares *weights* across invocations, but every
    invocation has its own KV cache (distinct activations at each depth) —
    caches are stacked on a leading invocation axis and dynamic-sliced."""
    flags = _hybrid_attn_flags(cfg)
    return jnp.cumsum(flags.astype(jnp.int32)) - 1


# --------------------------------------------------------------------------
# training / prefill forward
# --------------------------------------------------------------------------


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return jax.checkpoint(fn)  # "full"


def forward(params, tokens, cfg, *, embeds_prefix=None, remat="full",
            causal=True):
    """tokens: (B,S) -> hidden states (B,S,dm) and scalar moe aux."""
    x = embed_lookup(params["embed"], tokens).astype(cfg.cdtype)
    if embeds_prefix is not None:  # VLM: prepend projected patch embeddings
        pe = jnp.einsum("bpf,fd->bpd", embeds_prefix.astype(cfg.cdtype),
                        params["frontend_proj"]["w"].astype(cfg.cdtype))
        x = jnp.concatenate([pe, x], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    if cfg.family in ("dense", "moe", "vlm"):
        def body(carry, lp):
            y, aux = _remat(
                lambda q, w: _block_apply(w, q, cfg, positions, causal=causal)[:2],
                remat)(carry, lp)
            return constrain_acts(y), aux
        x, auxs = jax.lax.scan(body, x, params["blocks"])
        aux = jnp.sum(auxs)
    elif cfg.family == "ssm":
        def body(carry, lp):
            y, _ = _remat(
                lambda q, w: _mamba_block_apply(w, q, cfg), remat)(carry, lp)
            return constrain_acts(y), jnp.zeros((), F32)
        x, _ = jax.lax.scan(body, x, params["blocks"])
        aux = jnp.zeros((), F32)
    elif cfg.family == "hybrid":
        shared = params["shared_attn"]
        flags = _hybrid_attn_flags(cfg)

        def body(carry, xs_):
            lp, flag = xs_
            y, _ = _remat(lambda q, w: _mamba_block_apply(w, q, cfg), remat)(carry, lp)
            y = jax.lax.cond(
                flag,
                lambda q: _remat(lambda r, w: _block_apply(
                    w, r, cfg, positions, causal=causal)[0], remat)(q, shared),
                lambda q: q, y)
            return constrain_acts(y), jnp.zeros((), F32)
        x, _ = jax.lax.scan(body, x, (params["blocks"], flags))
        aux = jnp.zeros((), F32)
    else:
        raise ValueError(cfg.family)

    _, norm_fn = make_norm(cfg.norm, cfg.d_model, cfg.pdtype)
    x = norm_fn(params["final_norm"], x)
    return x, aux


def logits_fn(params, hidden, cfg):
    table = params["embed" if cfg.tie_embeddings else "unembed"]
    return unembed(table, hidden.astype(cfg.cdtype)).astype(F32)


def lm_loss(params, batch, cfg, *, remat="full", z_loss=1e-4,
            moe_aux_weight=0.01):
    """Teacher-forced LM loss. batch: tokens/targets/(mask)/(embeds)."""
    hidden, aux = forward(params, batch["tokens"], cfg,
                          embeds_prefix=batch.get("embeds"), remat=remat)
    if batch.get("embeds") is not None:
        hidden = hidden[:, batch["embeds"].shape[1]:]  # loss on text positions
    logits = logits_fn(params, hidden, cfg)
    targets = batch["targets"]
    mask = batch.get("mask", jnp.ones_like(targets, F32))
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    zl = z_loss * jnp.sum((lse * mask) ** 2)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll) / denom + zl / denom + moe_aux_weight * aux
    return loss, {"nll": jnp.sum(nll) / denom, "aux": aux}


# --------------------------------------------------------------------------
# serving: prefill + decode
# --------------------------------------------------------------------------


def init_cache(params, cfg, batch, max_len, dtype):
    """``dtype`` may be a jnp dtype or the symbolic "fp2fx8" string (int8
    FP2FX-quantized attention cache; SSM state stays float)."""
    sdtype = attn.cache_storage_dtype(dtype)
    if cfg.family in ("dense", "moe", "vlm"):
        c = attn.cache_init(cfg, batch, max_len, dtype)
        return {"blocks": jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape).copy(), c)}
    if cfg.family == "ssm":
        c = ssm_mod.ssm_cache_init(cfg, batch, sdtype)
        return {"blocks": jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape).copy(), c)}
    if cfg.family == "hybrid":
        c = ssm_mod.ssm_cache_init(cfg, batch, sdtype)
        blocks = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape).copy(), c)
        ninv = hybrid_n_invocations(cfg)
        sc = attn.cache_init(cfg, batch, max_len, dtype)
        shared = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (ninv,) + x.shape).copy(), sc)
        return {"blocks": blocks, "shared_attn": shared}
    raise ValueError(cfg.family)


def init_paged_cache(params, cfg, n_pages, page_size, dtype):
    """Paged serving cache: per-layer page pools + (no) block tables.

    Returns ``{"blocks": pools}`` with each attention leaf shaped
    ``(n_layers, n_pages + 1, Hkv, page_size, D)`` (page 0 = the null page).
    The caller owns the block tables and passes them in the cache dict as
    ``cache["block_tables"]`` (B, nb) — ``decode_step`` dispatches on their
    presence.  Attention families only: SSM/hybrid recurrent state is a
    fixed-size tensor, not a pageable stream (their serving stays dense).
    """
    if cfg.family not in ("dense", "moe", "vlm"):
        raise ValueError(
            f"kv_layout='paged' needs an attention-family model, got "
            f"family={cfg.family!r} (SSM/hybrid/encdec serve with the dense "
            f"slot-pool layout)")
    c = attn.paged_cache_init(cfg, n_pages, page_size, dtype)
    return {"blocks": jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape).copy(), c)}


def decode_step(params, cache, tokens1, pos, cfg, write_mask=None):
    """One decode step. tokens1: (B,1); pos: scalar int (current length) OR
    a (B,) vector of per-row lengths (ragged decode: every row attends over
    its own prefix and writes its KV at its own position).

    Returns (logits (B,1,V), new cache).  Attention layers append to their
    KV cache at ``pos`` and attend over [0, pos]; SSM layers update state.
    ``write_mask`` (B,) bool gates all cache/state writes per row — inactive
    slot-pool rows compute (masked, discarded) but never mutate their cache.
    """
    B = tokens1.shape[0]
    x = embed_lookup(params["embed"], tokens1).astype(cfg.cdtype)
    positions = (jnp.asarray(pos, jnp.int32).reshape(B, 1)
                 if jnp.ndim(pos) >= 1 else jnp.full((B, 1), pos, jnp.int32))

    if cfg.family in ("dense", "moe", "vlm"):
        bt = cache.get("block_tables")
        if bt is not None:  # paged: virtual KV length = blocks * page size
            max_len = bt.shape[1] * cache["blocks"]["k"].shape[3]
        else:
            max_len = cache["blocks"]["k"].shape[3]
        kv_mask = jnp.arange(max_len)[None, :] <= positions

        def body(carry, xs_):
            lp, lc = xs_
            y, _, nc = _block_apply(lp, carry, cfg, positions, causal=False,
                                    decode_cache=lc, pos_offset=pos,
                                    kv_len_mask=kv_mask,
                                    write_mask=write_mask, paged_bt=bt)
            return y, nc
        x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache["blocks"]))
        cache = ({"blocks": new_cache} if bt is None
                 else {"blocks": new_cache, "block_tables": bt})
    elif cfg.family == "ssm":
        def body(carry, xs_):
            lp, lc = xs_
            y, nc = _mamba_block_apply(lp, carry, cfg, decode_cache=lc,
                                       write_mask=write_mask)
            return y, nc
        x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache["blocks"]))
        cache = {"blocks": new_cache}
    elif cfg.family == "hybrid":
        shared = params["shared_attn"]
        sc = cache["shared_attn"]  # stacked (ninv, B, Hkv, S, D)
        max_len = sc["k"].shape[3]
        kv_mask = jnp.arange(max_len)[None, :] <= positions
        flags = _hybrid_attn_flags(cfg)
        inv_idx = _hybrid_inv_idx(cfg)

        def body(carry, xs_):
            lp, lc, flag, inv = xs_
            x_c, shared_cache = carry
            y, nc = _mamba_block_apply(lp, x_c, cfg, decode_cache=lc,
                                       write_mask=write_mask)

            def with_attn(args):
                q, scache = args
                inv_c = jnp.maximum(inv, 0)
                my = jax.tree.map(
                    lambda c: jax.lax.dynamic_index_in_dim(c, inv_c, 0,
                                                           keepdims=False),
                    scache)
                o, _, nsc = _block_apply(shared, q, cfg, positions,
                                         causal=False, decode_cache=my,
                                         pos_offset=pos, kv_len_mask=kv_mask,
                                         write_mask=write_mask)
                scache = jax.tree.map(
                    lambda c, n: jax.lax.dynamic_update_index_in_dim(
                        c, n.astype(c.dtype), inv_c, 0), scache, nsc)
                return o, scache
            y, shared_cache = jax.lax.cond(
                flag, with_attn, lambda a: a, (y, shared_cache))
            return (y, shared_cache), nc
        (x, sc), new_blocks = jax.lax.scan(
            body, (x, sc), (params["blocks"], cache["blocks"], flags, inv_idx))
        cache = {"blocks": new_blocks, "shared_attn": sc}
    else:
        raise ValueError(cfg.family)

    _, norm_fn = make_norm(cfg.norm, cfg.d_model, cfg.pdtype)
    x = norm_fn(params["final_norm"], x)
    return logits_fn(params, x, cfg), cache


def prefill_chunk(params, cache, tokens, start, cfg, lengths=None,
                  write_mask=None):
    """Chunked attend-at-offset: score a (B, S) token chunk in ONE forward
    pass against the full cached history — the single prefill-shaped
    primitive behind cold admission, prefix-hit suffixes, spec-decode
    verify, and the drafter's teacher sync.

    Row ``b``'s tokens write into the cache at ``start[b] .. start[b] +
    S - 1`` (write-then-attend, like ``decode_step``) and each token
    attends under its own causal frontier ``kv_index <= start[b] + j``, so
    the logits at lane ``j`` are exactly what a sequential decode would
    produce after feeding the first ``j`` chunk tokens.  ``lengths`` (B,)
    bounds each row's real tokens (ragged chunks; padded lanes never write
    and their logits are garbage the caller discards); ``write_mask`` (B,)
    gates whole rows (inactive slots compute but never mutate).  A prompt
    split across successive calls is bitwise identical to one call: every
    lane reads only cache content, and fp2fx8 quantization is
    per-(head, position), so chunk boundaries are invisible.  Spec-decode
    rollback needs no KV undo: rejected lanes sit past the row's advanced
    length, invisible to the ``kv_index <= position`` mask until
    overwritten.

    Returns (logits (B, S, V), cache).  Attention families run the one-pass
    masked chunk (dense or paged cache, fp2fx8 fused dequant,
    kernel/chunked/unfused dispatch via ``verify_attention``); SSM/hybrid
    state is a sequential recurrence, so those families scan gated
    ``decode_step``s — same contract, O(S) steps.
    """
    B, S = tokens.shape
    pos_b = (jnp.asarray(start, jnp.int32).reshape(B) if jnp.ndim(start) >= 1
             else jnp.full((B,), start, jnp.int32))
    nv = (jnp.full((B,), S, jnp.int32) if lengths is None
          else jnp.asarray(lengths, jnp.int32))
    if cfg.family not in ("dense", "moe", "vlm"):
        return _prefill_chunk_scan(
            params, cache, tokens, pos_b, cfg, nv, write_mask,
            lambda p, c, t, pos, wm: decode_step(p, c, t, pos, cfg,
                                                 write_mask=wm))
    x = embed_lookup(params["embed"], tokens).astype(cfg.cdtype)
    positions = pos_b[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
    bt = cache.get("block_tables")
    if bt is not None:  # paged: virtual KV length = blocks * page size
        max_len = bt.shape[1] * cache["blocks"]["k"].shape[3]
    else:
        max_len = cache["blocks"]["k"].shape[3]
    kv_mask = jnp.arange(max_len)[None, None, :] <= positions[:, :, None]
    _, norm_fn = make_norm(cfg.norm, cfg.d_model, cfg.pdtype)

    def body(carry, xs_):
        lp, lc = xs_
        h = norm_fn(lp["norms"]["pre_attn"], carry)
        q, k, v = attn.qkv_proj(lp["attn"], h, h, cfg, positions, positions)
        if bt is not None:
            nc = attn.cache_update_block_paged(lc, k, v, pos_b, bt, nv,
                                               write_mask)
        else:
            nc = attn.cache_update_block_ragged(lc, k, v, pos_b, nv,
                                                write_mask)
        o = attn.verify_attention(q, nc, cfg, kv_pos_mask=kv_mask,
                                  block_tables=bt)
        y = carry + attn.out_proj(lp["attn"], o.astype(carry.dtype))
        h2 = norm_fn(lp["norms"]["pre_mlp"], y)
        if "moe" in lp:
            z, _ = moe_mod.moe_apply(lp["moe"], h2, cfg)
        else:
            z = mlp_mod.mlp_apply(lp["mlp"], h2, cfg)
        return y + z.astype(y.dtype), nc

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache["blocks"]))
    cache = ({"blocks": new_cache} if bt is None
             else {"blocks": new_cache, "block_tables": bt})
    x = norm_fn(params["final_norm"], x)
    return logits_fn(params, x, cfg), cache


def _prefill_chunk_scan(params, cache, tokens, pos_b, cfg, nv, write_mask,
                        step_fn):
    """``prefill_chunk`` for recurrent-state families (and encdec): one
    gated ``decode_step`` per chunk lane.  Lane ``i`` feeds ``tokens[:, i]``
    at position ``pos_b + i`` with writes gated by
    ``write_mask & (i < nv)`` — exactly the per-lane mask the one-pass
    attention chunk applies, so the contract (and the stacked (B, S, V)
    logits) is identical, just O(S) sequential."""
    B, S = tokens.shape
    base = (jnp.ones((B,), bool) if write_mask is None
            else jnp.asarray(write_mask, bool))

    def body(cache_c, xs_):
        t, i = xs_
        wm = base & (i < nv)
        logits, cache_c = step_fn(params, cache_c, t[:, None], pos_b + i, wm)
        return cache_c, logits[:, -1, :]

    cache, logits = jax.lax.scan(
        body, cache, (tokens.T, jnp.arange(S, dtype=jnp.int32)))
    return logits.transpose(1, 0, 2), cache


def prefill(params, cache, tokens, cfg, lengths=None):
    """Fill the cache with a prompt; returns (last logits, cache, length).

    Attention-family models recompute K/V for the prompt in one pass and
    write them into the cache; SSM/hybrid run token-by-token state updates
    via ``decode_step`` semantics in a scan (cheap: O(S) with O(1) state).

    ``lengths`` (B,) enables *ragged* prefill: ``tokens`` is right-padded to
    a common S, each row's true prompt length is ``lengths[b]``, and the
    returned logits are taken at each row's position ``lengths[b] - 1``.
    The padded tail positions receive garbage K/V, but every consumer masks
    the cache with the ``kv_len_mask`` contract (``arange <= pos``), and
    decode overwrites a tail position in the same step that first exposes
    it — the garbage is never read.  SSM/hybrid gate their state updates per
    row instead (padded steps are no-ops), so the recurrent state is exactly
    the state after each row's true prompt.
    """
    B, S = tokens.shape
    if cfg.family in ("dense", "moe", "vlm"):
        x = embed_lookup(params["embed"], tokens).astype(cfg.cdtype)
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        _, norm_fn = make_norm(cfg.norm, cfg.d_model, cfg.pdtype)
        kv_mask = (None if lengths is None
                   else jnp.arange(S)[None, :] < lengths[:, None])

        def body(carry, xs_):
            lp, lc = xs_
            h = norm_fn(lp["norms"]["pre_attn"], carry)
            q, k, v = attn.qkv_proj(lp["attn"], h, h, cfg, positions, positions)
            nc = attn.cache_update(lc, k, v, 0)
            o = attn.attention_fwd(q, k, v, cfg, causal=True,
                                   kv_len_mask=kv_mask)
            y = carry + attn.out_proj(lp["attn"], o.astype(carry.dtype))
            h2 = norm_fn(lp["norms"]["pre_mlp"], y)
            if "moe" in lp:
                z, _ = moe_mod.moe_apply(lp["moe"], h2, cfg)
            else:
                z = mlp_mod.mlp_apply(lp["mlp"], h2, cfg)
            return y + z.astype(y.dtype), nc
        x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache["blocks"]))
        cache = {"blocks": new_cache}
        if lengths is not None:  # per-row last real position, then norm
            x = jnp.take_along_axis(x, (lengths - 1)[:, None, None], axis=1)
            x = norm_fn(params["final_norm"], x)
            return logits_fn(params, x, cfg), cache, S
        x = norm_fn(params["final_norm"], x)
        return logits_fn(params, x[:, -1:], cfg), cache, S

    if (lengths is None and cfg.parallel_prefill
            and cfg.family in ("ssm", "hybrid")
            and S % cfg.ssm_chunk == 0):  # padded tails would poison the state
        return _prefill_ssm_parallel(params, cache, tokens, cfg)

    # ssm / hybrid: naive sequential state build-up (baseline; see
    # parallel_prefill for the one-pass chunked-SSD fill — §Perf lever).
    # Ragged prompts gate each step per row: once a row runs past its true
    # length the write_mask freezes its state/KV, so padding is a no-op.
    def step(carry, t):
        cache_c, pos = carry
        wm = None if lengths is None else pos < lengths
        logits, nc = decode_step(params, cache_c, t[:, None], pos, cfg,
                                 write_mask=wm)
        return (nc, pos + 1), logits
    (cache, _), logits = jax.lax.scan(
        step, (cache, jnp.zeros((), jnp.int32)), tokens.T)
    if lengths is not None:  # logits: (S, B, 1, V) -> each row's step len-1
        lg = jnp.take_along_axis(logits[:, :, 0, :],
                                 (lengths - 1)[None, :, None], axis=0)
        return lg.transpose(1, 0, 2), cache, S
    return logits[-1], cache, S


def _prefill_ssm_parallel(params, cache, tokens, cfg):
    """One-pass prefill for SSM/hybrid: the chunked SSD forward computes the
    post-prompt state directly (``ssm_train(..., return_state=True)``);
    hybrid shared-attention K/V for the whole prompt land in the cache in one
    teacher-forced pass, exactly like the dense prefill."""
    B, S = tokens.shape
    x = embed_lookup(params["embed"], tokens).astype(cfg.cdtype)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    _, norm_fn = make_norm(cfg.norm, cfg.d_model, cfg.pdtype)

    if cfg.family == "ssm":
        def body(carry, lp):
            h = norm_fn(lp["norm"], carry)
            y, st = ssm_mod.ssm_train(lp["ssm"], h, cfg, return_state=True)
            return carry + y, st
        x, states = jax.lax.scan(body, x, params["blocks"])
        new_cache = {"blocks": jax.tree.map(
            lambda a, b: a.astype(b.dtype), states, cache["blocks"])}
    else:  # hybrid
        shared = params["shared_attn"]
        flags = _hybrid_attn_flags(cfg)
        inv_idx = _hybrid_inv_idx(cfg)
        sc = cache["shared_attn"]  # stacked (ninv, ...)

        def body(carry, xs_):
            lp, flag, inv = xs_
            x_c, scache = carry
            h = norm_fn(lp["norm"], x_c)
            y, st = ssm_mod.ssm_train(lp["ssm"], h, cfg, return_state=True)
            y = x_c + y

            def with_attn(args):
                q_in, scc = args
                inv_c = jnp.maximum(inv, 0)
                h2 = norm_fn(shared["norms"]["pre_attn"], q_in)
                q, k, v = attn.qkv_proj(shared["attn"], h2, h2, cfg,
                                        positions, positions)
                my = jax.tree.map(
                    lambda c: jax.lax.dynamic_index_in_dim(c, inv_c, 0,
                                                           keepdims=False),
                    scc)
                ncc = attn.cache_update(my, k, v, 0)
                scc = jax.tree.map(
                    lambda c, n: jax.lax.dynamic_update_index_in_dim(
                        c, n.astype(c.dtype), inv_c, 0), scc, ncc)
                o = attn.attention_fwd(q, k, v, cfg, causal=True)
                z2 = q_in + attn.out_proj(shared["attn"], o.astype(q_in.dtype))
                h3 = norm_fn(shared["norms"]["pre_mlp"], z2)
                return z2 + mlp_mod.mlp_apply(shared["mlp"], h3, cfg).astype(
                    z2.dtype), scc

            y, scache = jax.lax.cond(flag, with_attn, lambda a: a, (y, scache))
            return (y, scache), st

        (x, sc), states = jax.lax.scan(
            body, (x, sc), (params["blocks"], flags, inv_idx))
        new_cache = {"blocks": jax.tree.map(
            lambda a, b: a.astype(b.dtype), states, cache["blocks"]),
            "shared_attn": sc}

    x = norm_fn(params["final_norm"], x[:, :S])
    return logits_fn(params, x[:, -1:], cfg), new_cache, S
