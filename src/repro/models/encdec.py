"""Encoder-decoder stack (Whisper-style): stub conv frontend + enc + dec.

Per the assignment, the modality frontend is a STUB: ``input_specs()``
supplies precomputed frame embeddings (B, T_frames, frontend_dim); a single
projection stands in for the conv stack.  Encoder blocks are non-causal
self-attention; decoder blocks are causal self-attention + cross-attention
into the encoder memory.  Both softmaxes run through the registry (Hyft).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models.layers import embed_init, embed_lookup, make_norm, param, unembed
from repro.models.transformer import (_prefill_chunk_scan, _remat, _stack,
                                      logits_fn)

F32 = jnp.float32


def _enc_block_init(key, cfg):
    ks = jax.random.split(key, 3)
    n1, _ = make_norm(cfg.norm, cfg.d_model, cfg.pdtype)
    n2, _ = make_norm(cfg.norm, cfg.d_model, cfg.pdtype)
    return {"norms": {"pre_attn": n1, "pre_mlp": n2},
            "attn": attn.attn_init(ks[1], cfg, cfg.pdtype),
            "mlp": mlp_mod.mlp_init(ks[2], cfg, cfg.pdtype)}


def _dec_block_init(key, cfg):
    ks = jax.random.split(key, 4)
    n1, _ = make_norm(cfg.norm, cfg.d_model, cfg.pdtype)
    n2, _ = make_norm(cfg.norm, cfg.d_model, cfg.pdtype)
    n3, _ = make_norm(cfg.norm, cfg.d_model, cfg.pdtype)
    return {"norms": {"pre_attn": n1, "pre_cross": n2, "pre_mlp": n3},
            "attn": attn.attn_init(ks[1], cfg, cfg.pdtype),
            "cross": attn.attn_init(ks[2], cfg, cfg.pdtype),
            "mlp": mlp_mod.mlp_init(ks[3], cfg, cfg.pdtype)}


def init(key, cfg):
    ks = jax.random.split(key, 6)
    ek = jax.random.split(ks[0], cfg.enc_layers)
    dk = jax.random.split(ks[1], cfg.n_layers)
    fnorm_e, _ = make_norm(cfg.norm, cfg.d_model, cfg.pdtype)
    fnorm_d, _ = make_norm(cfg.norm, cfg.d_model, cfg.pdtype)
    return {
        "frontend_proj": {"w": param(ks[2], (cfg.frontend_dim, cfg.d_model),
                                     (None, "embed"), cfg.pdtype)},
        "enc_blocks": _stack([_enc_block_init(k, cfg) for k in ek]),
        "enc_norm": fnorm_e,
        "embed": embed_init(ks[3], cfg.vocab, cfg.d_model, cfg.pdtype),
        "dec_blocks": _stack([_dec_block_init(k, cfg) for k in dk]),
        "final_norm": fnorm_d,
    }


def encode(params, frames, cfg, remat="full"):
    """frames: (B, T, frontend_dim) -> memory (B, T, dm)."""
    x = jnp.einsum("btf,fd->btd", frames.astype(cfg.cdtype),
                   params["frontend_proj"]["w"].astype(cfg.cdtype))
    B, T, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    _, norm_fn = make_norm(cfg.norm, cfg.d_model, cfg.pdtype)

    def block(x_c, lp):
        h = norm_fn(lp["norms"]["pre_attn"], x_c)
        q, k, v = attn.qkv_proj(lp["attn"], h, h, cfg, positions, positions)
        o = attn.attention_fwd(q, k, v, cfg, causal=False)
        x_c = x_c + attn.out_proj(lp["attn"], o.astype(x_c.dtype))
        h = norm_fn(lp["norms"]["pre_mlp"], x_c)
        return x_c + mlp_mod.mlp_apply(lp["mlp"], h, cfg).astype(x_c.dtype)

    def body(carry, lp):
        return _remat(block, remat)(carry, lp), None
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return norm_fn(params["enc_norm"], x)


def decode_train(params, tokens, memory, cfg, remat="full"):
    """Teacher-forced decoder pass. tokens (B,S), memory (B,T,dm)."""
    x = embed_lookup(params["embed"], tokens).astype(cfg.cdtype)
    B, S, _ = x.shape
    Tm = memory.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    mem_pos = jnp.broadcast_to(jnp.arange(Tm, dtype=jnp.int32), (B, Tm))
    _, norm_fn = make_norm(cfg.norm, cfg.d_model, cfg.pdtype)

    def block(x_c, lp):
        h = norm_fn(lp["norms"]["pre_attn"], x_c)
        q, k, v = attn.qkv_proj(lp["attn"], h, h, cfg, positions, positions)
        o = attn.attention_fwd(q, k, v, cfg, causal=True)
        x_c = x_c + attn.out_proj(lp["attn"], o.astype(x_c.dtype))
        h = norm_fn(lp["norms"]["pre_cross"], x_c)
        q, k, v = attn.qkv_proj(lp["cross"], h, memory.astype(h.dtype), cfg,
                                positions, mem_pos)
        o = attn.attention_fwd(q, k, v, cfg, causal=False)
        x_c = x_c + attn.out_proj(lp["cross"], o.astype(x_c.dtype))
        h = norm_fn(lp["norms"]["pre_mlp"], x_c)
        return x_c + mlp_mod.mlp_apply(lp["mlp"], h, cfg).astype(x_c.dtype)

    def body(carry, lp):
        return _remat(block, remat)(carry, lp), None
    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    return norm_fn(params["final_norm"], x)


def loss(params, batch, cfg, *, remat="full", z_loss=1e-4, **_):
    memory = encode(params, batch["frames"], cfg, remat=remat)
    hidden = decode_train(params, batch["tokens"], memory, cfg, remat=remat)
    logits = logits_fn(params, hidden, cfg.with_(tie_embeddings=True))
    targets = batch["targets"]
    mask = batch.get("mask", jnp.ones_like(targets, F32))
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(nll) / denom + z_loss * jnp.sum((lse * mask) ** 2) / denom, \
        {"nll": jnp.sum(nll) / denom, "aux": jnp.zeros((), F32)}


def prefill_parallel(params, cache, batch, cfg):
    """One-pass prefill: encode once, then a teacher-forced decoder pass that
    writes the whole prompt's self-attention K/V into the cache (exactly the
    dense-LM prefill pattern) — vs. the baseline token-by-token scan.

    ``batch["lengths"]`` (B,) enables ragged prefill: right-padded prompts,
    per-row self-attention validity via the ``kv_len_mask`` contract, and
    logits gathered at each row's position ``lengths[b] - 1``.
    """
    memory = encode(params, batch["frames"], cfg, remat="none")
    tokens = batch["tokens"]
    lengths = batch.get("lengths")
    B, S = tokens.shape
    x = embed_lookup(params["embed"], tokens).astype(cfg.cdtype)
    Tm = memory.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    mem_pos = jnp.broadcast_to(jnp.arange(Tm, dtype=jnp.int32), (B, Tm))
    _, norm_fn = make_norm(cfg.norm, cfg.d_model, cfg.pdtype)
    mem_c = memory.astype(cfg.cdtype)
    kv_mask = (None if lengths is None
               else jnp.arange(S)[None, :] < lengths[:, None])

    def body(carry, xs_):
        lp, lc = xs_
        h = norm_fn(lp["norms"]["pre_attn"], carry)
        q, k, v = attn.qkv_proj(lp["attn"], h, h, cfg, positions, positions)
        nc = attn.cache_update(lc, k, v, 0)
        o = attn.attention_fwd(q, k, v, cfg, causal=True, kv_len_mask=kv_mask)
        y = carry + attn.out_proj(lp["attn"], o.astype(carry.dtype))
        h = norm_fn(lp["norms"]["pre_cross"], y)
        q, k, v = attn.qkv_proj(lp["cross"], h, mem_c, cfg, positions, mem_pos)
        o = attn.attention_fwd(q, k, v, cfg, causal=False)
        y = y + attn.out_proj(lp["cross"], o.astype(y.dtype))
        h = norm_fn(lp["norms"]["pre_mlp"], y)
        return y + mlp_mod.mlp_apply(lp["mlp"], h, cfg).astype(y.dtype), nc

    x, new_self = jax.lax.scan(body, x, (params["dec_blocks"], cache["self"]))
    if lengths is not None:
        x = jnp.take_along_axis(x, (lengths - 1)[:, None, None], axis=1)
    x = norm_fn(params["final_norm"], x)
    logits = logits_fn(params, x if lengths is not None else x[:, -1:],
                       cfg.with_(tie_embeddings=True))
    new_cache = {"self": new_self,
                 "memory": memory.astype(cache["memory"].dtype)}
    return logits, new_cache, S


def init_cache(params, cfg, batch, max_len, dtype):
    """``dtype`` may be a jnp dtype or "fp2fx8" (int8 FP2FX self-attention
    cache; the encoder memory stays float)."""
    c = attn.cache_init(cfg, batch, max_len, dtype)
    return {"self": jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape).copy(), c),
        "memory": jnp.zeros((batch, cfg.frontend_len, cfg.d_model),
                            attn.cache_storage_dtype(dtype))}


def decode_step(params, cache, tokens1, pos, cfg, write_mask=None):
    """One decoder token against a cached encoder memory + self KV cache.

    ``pos`` may be a (B,) vector (ragged slot-pool decode: per-row write
    position + attention prefix); ``write_mask`` (B,) gates the self-KV
    write per row (finished slots stop mutating their cache).
    """
    B = tokens1.shape[0]
    x = embed_lookup(params["embed"], tokens1).astype(cfg.cdtype)
    ragged = jnp.ndim(pos) >= 1
    positions = (jnp.asarray(pos, jnp.int32).reshape(B, 1) if ragged
                 else jnp.full((B, 1), pos, jnp.int32))
    memory = cache["memory"].astype(cfg.cdtype)
    Tm = memory.shape[1]
    mem_pos = jnp.broadcast_to(jnp.arange(Tm, dtype=jnp.int32), (B, Tm))
    max_len = cache["self"]["k"].shape[3]
    kv_mask = jnp.arange(max_len)[None, :] <= positions
    _, norm_fn = make_norm(cfg.norm, cfg.d_model, cfg.pdtype)

    def body(carry, xs_):
        lp, lc = xs_
        h = norm_fn(lp["norms"]["pre_attn"], carry)
        q, k, v = attn.qkv_proj(lp["attn"], h, h, cfg, positions, positions)
        if ragged or write_mask is not None:
            nc = attn.cache_update_ragged(
                lc, k, v, jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,)),
                write_mask)
        else:
            nc = attn.cache_update(lc, k, v, pos)
        o = attn.decode_attention(q, nc, cfg, kv_len_mask=kv_mask)
        y = carry + attn.out_proj(lp["attn"], o.astype(carry.dtype))
        h = norm_fn(lp["norms"]["pre_cross"], y)
        q, k, v = attn.qkv_proj(lp["cross"], h, memory, cfg, positions, mem_pos)
        o = attn.attention_fwd(q, k, v, cfg, causal=False)
        y = y + attn.out_proj(lp["cross"], o.astype(y.dtype))
        h = norm_fn(lp["norms"]["pre_mlp"], y)
        return y + mlp_mod.mlp_apply(lp["mlp"], h, cfg).astype(y.dtype), nc

    x, new_self = jax.lax.scan(body, x, (params["dec_blocks"], cache["self"]))
    x = norm_fn(params["final_norm"], x)
    logits = logits_fn(params, x, cfg.with_(tie_embeddings=True))
    return logits, {"self": new_self, "memory": cache["memory"]}


def prefill_chunk(params, cache, tokens, start, cfg, lengths=None,
                  write_mask=None):
    """Chunked attend-at-offset over the decoder (same contract as
    ``transformer.prefill_chunk``): lane ``i`` of the (B, S) chunk writes
    self-KV at ``start + i`` gated by ``write_mask & (i < lengths)`` and
    cross-attends the cached encoder memory — ``cache["memory"]`` must
    already hold each row's encoding.  Returns (logits (B, S, V), cache)."""
    B = tokens.shape[0]
    pos_b = (jnp.asarray(start, jnp.int32).reshape(B) if jnp.ndim(start) >= 1
             else jnp.full((B,), start, jnp.int32))
    nv = (jnp.full((B,), tokens.shape[1], jnp.int32) if lengths is None
          else jnp.asarray(lengths, jnp.int32))
    return _prefill_chunk_scan(
        params, cache, tokens, pos_b, cfg, nv, write_mask,
        lambda p, c, t, pos, wm: decode_step(p, c, t, pos, cfg,
                                             write_mask=wm))
