"""Model zoo: one uniform interface over every architecture family.

``build_model(cfg)`` returns a ``Model`` namespace with:
  init(key)                        -> boxed param pytree (Param leaves)
  loss(params, batch, **opts)      -> (scalar, metrics)   [train step body]
  prefill(params, cache, batch)    -> (logits, cache, len)
  decode_step(params, cache, tok, pos) -> (logits, cache)
  init_cache(params, batch, max_len, dtype)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, transformer


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable
    loss: Callable
    prefill: Callable
    decode_step: Callable
    init_cache: Callable
    # paged serving cache (attention families only; None = layout unsupported)
    init_paged_cache: Any = None
    # chunked attend-at-offset prefill: write a (B, S) token chunk at
    # per-row positions and attend the full cached history (the one
    # primitive behind admission, prefix-hit suffixes, spec verify, and
    # drafter sync) — (p, c, tokens, start, lengths=, write_mask=) ->
    # (logits (B, S, V), cache)
    prefill_chunk: Any = None
    # encdec only: (params, frames) -> encoder memory (chunked admission
    # installs it into the slot cache before any prefill_chunk call)
    encode: Any = None


def resolve_attn_mode(model: Model, attn_mode) -> Model:
    """Rebuild the model with an attention-mode override (no-op when the
    override is unset or already active).  ``attn_mode="kernel"`` keeps
    prefill, masked decode, and the training backward on the fused Pallas
    path (the mask/stats contract in ``repro.kernels.ops``)."""
    if attn_mode and attn_mode != model.cfg.attn_mode:
        model = build_model(model.cfg.with_(attn_mode=attn_mode))
    return model


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family == "encdec":
        return Model(
            cfg=cfg,
            init=lambda key: encdec.init(key, cfg),
            loss=lambda p, b, **kw: encdec.loss(p, b, cfg, **kw),
            prefill=_encdec_prefill(cfg),
            decode_step=lambda p, c, t, pos, **kw: encdec.decode_step(
                p, c, t, pos, cfg, **kw),
            init_cache=lambda p, batch, max_len, dtype: encdec.init_cache(
                p, cfg, batch, max_len, dtype),
            prefill_chunk=lambda p, c, t, start, **kw: encdec.prefill_chunk(
                p, c, t, start, cfg, **kw),
            encode=lambda p, frames: encdec.encode(p, frames, cfg,
                                                   remat="none"),
        )
    return Model(
        cfg=cfg,
        init=lambda key: transformer.init(key, cfg),
        loss=lambda p, b, **kw: transformer.lm_loss(p, b, cfg, **kw),
        prefill=lambda p, c, b: transformer.prefill(
            p, c, b["tokens"], cfg, lengths=b.get("lengths")),
        decode_step=lambda p, c, t, pos, **kw: transformer.decode_step(
            p, c, t, pos, cfg, **kw),
        init_cache=lambda p, batch, max_len, dtype: transformer.init_cache(
            p, cfg, batch, max_len, dtype),
        init_paged_cache=(
            (lambda p, n_pages, page_size, dtype: transformer.init_paged_cache(
                p, cfg, n_pages, page_size, dtype))
            if cfg.family in ("dense", "moe", "vlm") else None),
        prefill_chunk=lambda p, c, t, start, **kw: transformer.prefill_chunk(
            p, c, t, start, cfg, **kw),
    )


def _encdec_prefill(cfg):
    def fn(params, cache, batch):
        if cfg.parallel_prefill:
            return encdec.prefill_parallel(params, cache, batch, cfg)
        memory = encdec.encode(params, batch["frames"], cfg, remat="none")
        cache = dict(cache, memory=memory.astype(cache["memory"].dtype))
        # baseline: run prompt tokens through decode steps one at a time;
        # ragged prompts (batch["lengths"]) gate each row's writes past its
        # true length and gather its logits at step lengths-1
        tokens = batch["tokens"]
        lengths = batch.get("lengths")

        def step(carry, t):
            c, pos = carry
            wm = None if lengths is None else pos < lengths
            logits, nc = encdec.decode_step(params, c, t[:, None], pos, cfg,
                                            write_mask=wm)
            return (nc, pos + 1), logits
        (cache, n), logits = jax.lax.scan(
            step, (cache, jnp.zeros((), jnp.int32)), tokens.T)
        if lengths is not None:  # (S, B, 1, V) -> each row's step len-1
            lg = jnp.take_along_axis(logits[:, :, 0, :],
                                     (lengths - 1)[None, :, None], axis=0)
            return lg.transpose(1, 0, 2), cache, tokens.shape[1]
        return logits[-1], cache, tokens.shape[1]
    return fn
