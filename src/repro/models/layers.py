"""Model building blocks: boxed params, norms, rotary, activations, dense.

Parameters are ``Param`` pytree nodes carrying *logical* sharding axes as
static aux data; ``unbox`` strips them for compute, and
``repro.distributed.sharding`` maps logical axes -> mesh ``PartitionSpec``
via per-arch rules.  This is the flax ``nn.Partitioned`` pattern without the
flax dependency (only jax/numpy are available offline).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

F32 = jnp.float32


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Param:
    """A parameter with logical axis names (static metadata)."""

    value: Any
    axes: tuple

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], tuple(aux))


def is_param(x) -> bool:
    return isinstance(x, Param)


def unbox(tree):
    """Strip Param boxes -> plain array pytree (what compute functions take)."""
    return jax.tree.map(lambda p: p.value if is_param(p) else p, tree,
                        is_leaf=is_param)


def boxed_axes(tree):
    """Param boxes -> logical-axes pytree (same structure as unbox(tree))."""
    return jax.tree.map(lambda p: p.axes if is_param(p) else None, tree,
                        is_leaf=is_param)


def param(key, shape, axes, dtype=F32, scale: float | None = None,
          init: str = "normal") -> Param:
    """Initialize one parameter. ``scale=None`` -> fan-in 1/sqrt(shape[0])."""
    if init == "zeros":
        return Param(jnp.zeros(shape, dtype), axes)
    if init == "ones":
        return Param(jnp.ones(shape, dtype), axes)
    s = scale if scale is not None else (shape[0] ** -0.5 if shape else 1.0)
    return Param((jax.random.normal(key, shape, F32) * s).astype(dtype), axes)


# --------------------------------------------------------------------------
# norms — always computed in fp32 (standard mixed-precision practice)
# --------------------------------------------------------------------------


def rmsnorm_init(dm, dtype):
    return {"scale": Param(jnp.ones((dm,), dtype), ("embed",))}


def rmsnorm(p, x, eps=1e-6):
    x32 = x.astype(F32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(F32)).astype(x.dtype)


def layernorm_init(dm, dtype, bias=True):
    p = {"scale": Param(jnp.ones((dm,), dtype), ("embed",))}
    if bias:
        p["bias"] = Param(jnp.zeros((dm,), dtype), ("embed",))
    return p


def layernorm(p, x, eps=1e-5):
    x32 = x.astype(F32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(F32)
    if "bias" in p:
        y = y + p["bias"].astype(F32)
    return y.astype(x.dtype)


def np_layernorm(x, eps=1e-5):
    """Non-parametric LayerNorm (OLMo): no scale, no bias."""
    x32 = x.astype(F32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def make_norm(kind: str, dm: int, dtype):
    """Returns (init_params, apply_fn)."""
    if kind == "rms":
        return rmsnorm_init(dm, dtype), rmsnorm
    if kind == "ln":
        return layernorm_init(dm, dtype), layernorm
    if kind == "np_ln":
        return {}, lambda p, x: np_layernorm(x)
    raise ValueError(kind)


# --------------------------------------------------------------------------
# rotary position embeddings
# --------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=F32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: (..., S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    ang = positions[..., None].astype(F32) * freqs  # (..., S, D/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    y1 = x1.astype(F32) * cos - x2.astype(F32) * sin
    y2 = x2.astype(F32) * cos + x1.astype(F32) * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# --------------------------------------------------------------------------
# activations
# --------------------------------------------------------------------------


def squared_relu(x):
    r = jax.nn.relu(x)
    return r * r


ACTIVATIONS: dict[str, Callable] = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
    "squared_relu": squared_relu,
}


# --------------------------------------------------------------------------
# dense / embedding
# --------------------------------------------------------------------------


def dense_init(key, d_in, d_out, axes, dtype, bias=False):
    p = {"w": param(key, (d_in, d_out), axes, dtype)}
    if bias:
        p["b"] = Param(jnp.zeros((d_out,), dtype), (axes[-1],))
    return p


def dense(p, x):
    y = jnp.einsum("...i,io->...o", x, p["w"].astype(x.dtype))
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def embed_init(key, vocab, dm, dtype):
    return {"table": param(key, (vocab, dm), ("vocab", "embed"), dtype, scale=1.0)}


def embed_lookup(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)


def unembed(p, x):
    """Project to vocab logits (tied or untied table of shape (V, dm))."""
    return jnp.einsum("...d,vd->...v", x, p["table"].astype(x.dtype))
