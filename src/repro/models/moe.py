"""Mixture-of-Experts block: top-k router + capacity-bounded einsum dispatch.

Expert weights are stacked on a leading ``experts`` axis and sharded over the
``model`` mesh axis (expert parallelism); the dispatch/combine einsums
contract over (tokens x experts x capacity), so GSPMD inserts the
all-to-all.  The router softmax goes through the registry — i.e. **the Hyft
accelerator also serves the router**, the paper's technique applied at a
second site (DESIGN.md §5).

The router uses top-k *after* the full softmax (Mixtral/Grok convention:
softmax over all experts, renormalize over the chosen k).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.registry import get_softmax
from repro.models.layers import ACTIVATIONS, param

F32 = jnp.float32


def moe_init(key, cfg, dtype):
    ks = jax.random.split(key, 4)
    dm, dff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    p = {
        "router": param(ks[0], (dm, E), ("embed", "experts_dim"), F32),
        "w_up": param(ks[1], (E, dm, dff), ("experts", "embed", "mlp"), dtype),
        "w_down": param(ks[2], (E, dff, dm), ("experts", "mlp", "embed"),
                        dtype, scale=dff ** -0.5),
    }
    if cfg.mlp_gated:
        p["w_gate"] = param(ks[3], (E, dm, dff), ("experts", "embed", "mlp"), dtype)
    return p


def moe_apply(p, x, cfg):
    """x: (B, S, dm) -> (out, aux) with load-balancing aux loss.

    Tokens are regrouped into fixed-size dispatch groups (Switch/MaxText
    style) so the one-hot dispatch tensor is O(tokens * E * cap_per_group)
    instead of O(tokens * E * cap_per_sequence).
    """
    B0, S0, dm = x.shape
    G = min(getattr(cfg, "moe_group", 512), B0 * S0)
    x = x.reshape(-1, G, dm)
    B, S, _ = x.shape
    E, k = cfg.n_experts, cfg.moe_top_k
    cap = max(1, int(cfg.capacity_factor * S * k / E))
    act = ACTIVATIONS[cfg.act]

    logits = jnp.einsum("bsd,de->bse", x.astype(F32), p["router"])
    probs = get_softmax(cfg.softmax_impl)(logits).astype(F32)  # Hyft router
    gate_vals, gate_idx = jax.lax.top_k(probs, k)              # (B,S,k)
    gate_vals = gate_vals / (jnp.sum(gate_vals, -1, keepdims=True) + 1e-9)

    # capacity-bounded one-hot dispatch (Switch-style, deterministic)
    onehot = jax.nn.one_hot(gate_idx, E, dtype=F32)            # (B,S,k,E)
    pos = jnp.cumsum(onehot.reshape(B, S * k, E), axis=1).reshape(B, S, k, E)
    pos = pos * onehot - 1.0                                   # slot per (token,choice)
    keep = (pos >= 0) & (pos < cap)
    slot = jax.nn.one_hot(jnp.where(keep, pos, -1), cap, dtype=F32)  # (B,S,k,E,cap)

    disp = jnp.einsum("bske,bskec->bsec", onehot * keep, slot)  # (B,S,E,cap)
    comb = jnp.einsum("bsk,bske,bskec->bsec", gate_vals, onehot * keep, slot)

    xe = jnp.einsum("bsec,bsd->becd", disp.astype(x.dtype), x)  # (B,E,cap,dm)
    up = jnp.einsum("becd,edf->becf", xe, p["w_up"].astype(x.dtype))
    if "w_gate" in p:
        gate = jnp.einsum("becd,edf->becf", xe, p["w_gate"].astype(x.dtype))
        h = act(gate) * up
    else:
        h = act(up)
    ye = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(x.dtype))
    y = jnp.einsum("bsec,becd->bsd", comb.astype(x.dtype), ye)

    # Switch-style load-balancing loss
    density = jnp.mean(onehot[..., 0, :], axis=(0, 1)) if k == 1 else \
        jnp.mean(jnp.sum(onehot, axis=2), axis=(0, 1)) / k
    router_mean = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(density * router_mean)
    return y.reshape(B0, S0, dm), aux
