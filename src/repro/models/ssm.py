"""Mamba2 (SSD — state-space duality) block, chunked matmul formulation.

The chunked SSD algorithm is the MXU-friendly form: intra-chunk attention-
like quadratic term + inter-chunk state recurrence (lax.scan over chunks).
Softmax-free — the Hyft technique is *inapplicable* here by design (DESIGN.md
§5); the block still exercises sharding, remat, and long-context decode.

Decode is O(1) per token: a single state update carried in the cache, which
is what makes the ``long_500k`` cell runnable for SSM/hybrid archs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import Param, param

F32 = jnp.float32


def ssm_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    conv_dim = d_inner + 2 * cfg.ssm_state
    return d_inner, n_heads, conv_dim


def ssm_init(key, cfg, dtype):
    ks = jax.random.split(key, 5)
    dm, N, K = cfg.d_model, cfg.ssm_state, cfg.ssm_conv
    d_inner, H, conv_dim = ssm_dims(cfg)
    proj_out = 2 * d_inner + 2 * N + H  # z, x, B, C, dt
    return {
        "in_proj": param(ks[0], (dm, proj_out), ("embed", "mlp"), dtype),
        "conv_w": param(ks[1], (K, conv_dim), (None, "mlp"), dtype,
                        scale=K ** -0.5),
        "conv_b": Param(jnp.zeros((conv_dim,), dtype), ("mlp",)),
        "A_log": Param(jnp.log(jnp.linspace(1.0, 16.0, H)).astype(F32), ("heads",)),
        "D": Param(jnp.ones((H,), F32), ("heads",)),
        "dt_bias": Param(jnp.zeros((H,), F32), ("heads",)),
        "norm_scale": Param(jnp.ones((d_inner,), dtype), ("mlp",)),
        "out_proj": param(ks[2], (d_inner, dm), ("mlp", "embed"), dtype,
                          scale=d_inner ** -0.5),
    }


def _split_proj(proj, cfg):
    d_inner, H, _ = ssm_dims(cfg)
    N = cfg.ssm_state
    z, xs, Bm, Cm, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N], axis=-1)
    return z, xs, Bm, Cm, dt


def _gated_norm(p, y, z, eps=1e-6):
    y32 = (y * jax.nn.silu(z.astype(F32))).astype(F32)
    var = jnp.mean(y32 * y32, axis=-1, keepdims=True)
    return (y32 * jax.lax.rsqrt(var + eps) * p["norm_scale"].astype(F32))


def ssm_train(p, x, cfg, return_state=False):
    """x: (B,S,dm) -> (B,S,dm); causal depthwise conv + chunked SSD.

    ``return_state=True`` also returns the decode cache after the prompt:
    the final SSD state (B,H,P,N) and the last K-1 pre-conv columns — this
    is what makes *parallel prefill* possible for SSM archs (vs. the naive
    token-by-token scan)."""
    Bsz, S, _ = x.shape
    d_inner, H, conv_dim = ssm_dims(cfg)
    N, P, Q = cfg.ssm_state, cfg.ssm_head_dim, cfg.ssm_chunk
    proj = jnp.einsum("bsd,dp->bsp", x, p["in_proj"].astype(x.dtype))
    z, xs, Bm, Cm, dt = _split_proj(proj, cfg)
    # causal depthwise conv over (x, B, C)
    xbc = jnp.concatenate([xs, Bm, Cm], axis=-1)
    K = cfg.ssm_conv
    conv_tail = xbc[:, S - (K - 1):, :] if K > 1 else xbc[:, :0, :]
    xbc_pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    conv = sum(xbc_pad[:, i:i + S] * p["conv_w"][i].astype(x.dtype)
               for i in range(K)) + p["conv_b"].astype(x.dtype)
    conv = jax.nn.silu(conv.astype(F32))
    xs, Bm, Cm = jnp.split(conv, [d_inner, d_inner + N], axis=-1)

    A = -jnp.exp(p["A_log"])                                   # (H,) < 0
    dt = jax.nn.softplus(dt.astype(F32) + p["dt_bias"])        # (B,S,H)
    nC = S // Q
    xh = xs.reshape(Bsz, nC, Q, H, P)
    dtc = dt.reshape(Bsz, nC, Q, H)
    Bc = Bm.reshape(Bsz, nC, Q, N)
    Cc = Cm.reshape(Bsz, nC, Q, N)
    dA = dtc * A                                               # (B,c,Q,H) <= 0
    cum = jnp.cumsum(dA, axis=2)
    xdt = xh * dtc[..., None]

    # intra-chunk (quadratic, MXU): M[i,j] = (C_i . B_j) exp(cum_i - cum_j), i>=j
    G = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)
    ldecay = cum[:, :, :, None, :] - cum[:, :, None, :, :]          # (B,c,Q,K,H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    # mask in log space *before* exp: exp of masked +large would give inf and
    # poison the gradient through the where (inf * 0 -> NaN)
    ldecay = jnp.where(mask[None, None, :, :, None], ldecay, -jnp.inf)
    M = G[..., None] * jnp.exp(ldecay)
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", M, xdt)

    # chunk boundary states + inter-chunk scan
    decay_end = jnp.exp(cum[:, :, -1:, :] - cum)               # (B,c,Q,H)
    chunk_state = jnp.einsum("bckn,bckh,bckhp->bchpn", Bc, decay_end, xdt)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                    # (B,c,H)

    def body(h_prev, xs_):
        cs, cd = xs_
        h_new = cd[:, :, None, None] * h_prev + cs
        return h_new, h_prev

    h0 = jnp.zeros((Bsz, H, P, N), F32)
    h_final, h_prevs = jax.lax.scan(
        body, h0, (chunk_state.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(1, 0, 2)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)                 # (B,c,H,P,N)
    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", Cc, jnp.exp(cum), h_prevs)

    y = (y_intra + y_inter + xh.astype(F32) * p["D"][None, None, None, :, None])
    y = y.reshape(Bsz, S, d_inner)
    out = _gated_norm(p, y, z)
    out = jnp.einsum("bsp,pd->bsd", out.astype(x.dtype),
                     p["out_proj"].astype(x.dtype))
    if return_state:
        return out, {"ssm": h_final, "conv": conv_tail}
    return out


def ssm_cache_init(cfg, batch, dtype):
    d_inner, H, conv_dim = ssm_dims(cfg)
    return {"ssm": jnp.zeros((batch, H, cfg.ssm_head_dim, cfg.ssm_state), F32),
            "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype)}


def ssm_decode(p, x1, cache, cfg):
    """Single-token step. x1: (B,1,dm) -> (B,1,dm), updated cache."""
    Bsz = x1.shape[0]
    d_inner, H, conv_dim = ssm_dims(cfg)
    N, P = cfg.ssm_state, cfg.ssm_head_dim
    proj = jnp.einsum("bsd,dp->bsp", x1, p["in_proj"].astype(x1.dtype))
    z, xs, Bm, Cm, dt = _split_proj(proj, cfg)
    xbc = jnp.concatenate([xs, Bm, Cm], axis=-1)[:, 0]         # (B, conv_dim)
    window = jnp.concatenate([cache["conv"], xbc[:, None]], axis=1)  # (B,K,conv)
    conv = jnp.einsum("bkc,kc->bc", window.astype(F32),
                      p["conv_w"].astype(F32)) + p["conv_b"].astype(F32)
    conv = jax.nn.silu(conv)
    xs, Bm, Cm = jnp.split(conv, [d_inner, d_inner + N], axis=-1)
    A = -jnp.exp(p["A_log"])
    dtv = jax.nn.softplus(dt[:, 0].astype(F32) + p["dt_bias"])  # (B,H)
    xh = xs.reshape(Bsz, H, P)
    dA = jnp.exp(dtv * A)                                       # (B,H)
    h = cache["ssm"] * dA[:, :, None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dtv, xh, Bm)
    y = jnp.einsum("bn,bhpn->bhp", Cm, h) + xh * p["D"][None, :, None]
    y = y.reshape(Bsz, 1, d_inner)
    out = _gated_norm(p, y, z)
    out = jnp.einsum("bsp,pd->bsd", out.astype(x1.dtype),
                     p["out_proj"].astype(x1.dtype))
    return out, {"ssm": h, "conv": window[:, 1:]}
