"""Model zoo for the 10 assigned architectures + the paper's BERT proxy.

Lazy re-exports to avoid a circular import with distributed.sharding
(which needs models.layers at module scope).
"""


def __getattr__(name):
    if name in ("Model", "build_model", "resolve_attn_mode"):
        from repro.models import model_zoo
        return getattr(model_zoo, name)
    raise AttributeError(name)
