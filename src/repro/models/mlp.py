"""MLP blocks: gated (SwiGLU-family) and plain (squared-ReLU / GeLU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import ACTIVATIONS, param


def mlp_init(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    dm, dff = cfg.d_model, cfg.d_ff
    p = {"w_up": param(ks[0], (dm, dff), ("embed", "mlp"), dtype),
         "w_down": param(ks[1], (dff, dm), ("mlp", "embed"), dtype,
                         scale=dff ** -0.5)}
    if cfg.mlp_gated:
        p["w_gate"] = param(ks[2], (dm, dff), ("embed", "mlp"), dtype)
    return p


def mlp_apply(p, x, cfg):
    act = ACTIVATIONS[cfg.act]
    up = jnp.einsum("...d,df->...f", x, p["w_up"].astype(x.dtype))
    if "w_gate" in p:
        gate = jnp.einsum("...d,df->...f", x, p["w_gate"].astype(x.dtype))
        h = act(gate) * up
    else:
        h = act(up)
    return jnp.einsum("...f,fd->...d", h, p["w_down"].astype(x.dtype))
