"""Attention: GQA/MHA/cross, pluggable softmax, KV cache, three fwd modes.

Modes (``AttnMode``):
  unfused  — QK^T -> registry softmax (hyft/exact/...) -> PV.  The
             paper-faithful training path: the softmax VJP is the
             accelerator's reused DIV/MUL datapath (custom_vjp in core),
             while the surrounding matmuls stay on the MXU.
  chunked  — lax.scan over KV chunks with online Hyft (max,sum,acc) carry;
             the pure-JAX twin of the fused Pallas kernel.  Lowerable in the
             multi-pod dry-run (Pallas can't lower to the CPU backend) and
             differentiable via a recompute-based custom VJP (flash-style
             backward using the saved row stats).  This is the beyond-paper
             memory-roofline lever for long sequences.
  kernel   — the Pallas flash kernel (TPU runtime; interpret mode in tests).

Sequence-parallel decode (``sp_decode_attention``) implements the paper's
L1/L2 Hyft tree *across devices*: each model-axis shard computes local
(max, fixed-sum, acc) Hyft stats over its KV-cache slice; a pmax/psum pair
merges them — 2 scalars + one (D,)-vector per row over ICI instead of
all-gathering the scores.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import numerics as nm
from repro.core.hyft import HyftConfig
from repro.core.registry import get_softmax, hyft_config_for
from repro.kernels.flash_attention import hyft_alpha, hyft_finalize
from repro.models.layers import Param, param

F32 = jnp.float32
I32 = jnp.int32
NEG_BIG = -3.0e38


def attn_init(key, cfg, dtype):
    ks = jax.random.split(key, 4)
    dm, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    p = {
        "wq": param(ks[0], (dm, hq, dh), ("embed", "heads", "head_dim"), dtype),
        "wk": param(ks[1], (dm, hkv, dh), ("embed", "kv_heads", "head_dim"), dtype),
        "wv": param(ks[2], (dm, hkv, dh), ("embed", "kv_heads", "head_dim"), dtype),
        "wo": param(ks[3], (hq, dh, dm), ("heads", "head_dim", "embed"), dtype,
                    scale=(hq * dh) ** -0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = Param(jnp.zeros((hq, dh), dtype), ("heads", "head_dim"))
        p["bk"] = Param(jnp.zeros((hkv, dh), dtype), ("kv_heads", "head_dim"))
        p["bv"] = Param(jnp.zeros((hkv, dh), dtype), ("kv_heads", "head_dim"))
    return p


def qkv_proj(p, x, kv_x, cfg, positions, kv_positions):
    """x: (B,S,dm) -> q (B,Hq,S,D); kv_x -> k,v (B,Hkv,Sk,D), rope'd."""
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhe->bshe", kv_x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhe->bshe", kv_x, p["wv"].astype(x.dtype))
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.rope_theta:
        q = _rope(q, positions, cfg.rope_theta)
        k = _rope(k, kv_positions, cfg.rope_theta)
    # -> (B, H, S, D)
    return (q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3))


def _rope(x, positions, theta):
    from repro.models.layers import apply_rope
    return apply_rope(x, positions, theta)


def out_proj(p, o):
    """o: (B,H,S,D) -> (B,S,dm)."""
    return jnp.einsum("bhsd,hde->bse", o, p["wo"].astype(o.dtype))


# --------------------------------------------------------------------------
# mode 1: unfused (paper-faithful)
# --------------------------------------------------------------------------


def unfused_attention(q, k, v, softmax_impl: str, *, causal: bool,
                      q_offset=0, kv_len_mask=None):
    """q (B,Hq,Sq,D), k/v (B,Hkv,Sk,D); softmax over full score rows."""
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    g = Hq // Hkv
    qg = q.reshape(B, Hkv, g, Sq, D)
    z = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(F32), k.astype(F32)) * (D ** -0.5)
    if causal:
        qi = q_offset + jax.lax.broadcasted_iota(I32, (Sq, Sk), 0)
        ki = jax.lax.broadcasted_iota(I32, (Sq, Sk), 1)
        z = jnp.where(qi >= ki, z, NEG_BIG)
    if kv_len_mask is not None:  # (B, Sk) bool — decode cache validity
        z = jnp.where(kv_len_mask[:, None, None, None, :], z, NEG_BIG)
    p = get_softmax(softmax_impl)(z).astype(F32)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(F32))
    return o.reshape(B, Hq, Sq, D).astype(q.dtype)


# --------------------------------------------------------------------------
# mode 2: chunked online-Hyft (pure JAX; scan over KV chunks) + custom VJP
# --------------------------------------------------------------------------


def _hyft_chunk_stats(z, cfg: HyftConfig, m_run):
    """One KV chunk: Hyft stages 1-2 against running max. Returns
    (m_new raw, alpha fp32, addend-sum fp32@acc-grid, p fp32)."""
    z_raw = nm.fp2fx(z, cfg.frac_bits, cfg.total_bits)
    zsub = z_raw[..., :: cfg.step] if cfg.step > 1 else z_raw
    blk_max = jnp.max(zsub, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_run, blk_max)
    e, m = nm.exp_unit(z_raw - m_new, cfg.frac_bits, cfg.mant_bits)
    addend = nm.expfloat_to_fx(e, m, cfg.mant_bits, cfg.acc_bits)
    l_blk = jnp.sum(addend, axis=-1, keepdims=True)
    alpha = hyft_alpha(m_run - m_new, cfg)
    p = ((1 << cfg.mant_bits) + m).astype(F32) * nm.pow2_float(e - cfg.mant_bits)
    return m_new, alpha, l_blk, p


# stage-3 finalize is shared with the fused kernels (one arithmetic for every
# online mode: chunked, fused, split-K decode, sequence-parallel)
_hyft_finalize = hyft_finalize


def _mask_chunks(kv_len_mask, B, nk, chunk):
    """(B, Sk) float mask -> (nk, B, chunk) scan slices; a 3D (B, Sq, Sk)
    per-query-row mask (the verify path) -> (nk, B, Sq, chunk).  None passes
    through."""
    if kv_len_mask is None:
        return None
    if kv_len_mask.ndim == 3:
        Sq = kv_len_mask.shape[1]
        return kv_len_mask.reshape(B, Sq, nk, chunk).transpose(2, 0, 1, 3)
    return kv_len_mask.reshape(B, nk, chunk).transpose(1, 0, 2)


def _mask_bcast(mt):
    """One scan slice of ``_mask_chunks`` broadcast against z
    (B, Hkv, g, Sq, chunk): (B, chunk) masks every query row, (B, Sq, chunk)
    masks per query row."""
    if mt.ndim == 3:
        return mt[:, None, None, :, :]
    return mt[:, None, None, None, :]


def _chunked_fwd(q, k, v, cfg: HyftConfig, causal: bool, chunk: int, q_offset,
                 kv_len_mask=None):
    """Returns (o, m_final raw, l_final). Shapes: q (B,Hq,Sq,D), k/v GQA."""
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    g = Hq // Hkv
    nk = Sk // chunk
    qg = q.reshape(B, Hkv, g, Sq, D).astype(F32) * (D ** -0.5)
    kc = k.reshape(B, Hkv, nk, chunk, D).transpose(2, 0, 1, 3, 4).astype(F32)
    vc = v.reshape(B, Hkv, nk, chunk, D).transpose(2, 0, 1, 3, 4).astype(F32)
    mc = _mask_chunks(kv_len_mask, B, nk, chunk)

    def body(carry, xs):
        m_run, l_run, acc = carry
        j, kt, vt, mt = xs
        z = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kt)
        if causal:
            qi = q_offset + jax.lax.broadcasted_iota(I32, (Sq, chunk), 0)
            ki = jax.lax.broadcasted_iota(I32, (Sq, chunk), 1) + j * chunk
            z = jnp.where((qi >= ki)[None, None, None], z, NEG_BIG)
        if mt is not None:  # pre-FP2FX, same as the unfused path
            z = jnp.where(_mask_bcast(mt) > 0, z, NEG_BIG)
        m_new, alpha, l_blk, p = _hyft_chunk_stats(z, cfg, m_run)
        l_run = nm.fx_quantize(l_run * alpha, cfg.acc_bits) + l_blk
        acc = acc * alpha + jnp.einsum("bhgqk,bhkd->bhgqd", p, vt)
        return (m_new, l_run, acc), None

    m0 = jnp.full((B, Hkv, g, Sq, 1), -(2 ** (cfg.total_bits - 1)), I32)
    l0 = jnp.zeros((B, Hkv, g, Sq, 1), F32)
    a0 = jnp.zeros((B, Hkv, g, Sq, D), F32)
    (m_f, l_f, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (jnp.arange(nk), kc, vc, mc))
    o = _hyft_finalize(acc, l_f, cfg).reshape(B, Hq, Sq, D)
    return o, m_f, l_f


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def chunked_hyft_attention(q, k, v, cfg: HyftConfig, causal: bool = True,
                           chunk: int = 512, q_offset: int = 0,
                           kv_len_mask=None):
    """Online-Hyft attention, O(chunk) memory in the KV dimension.

    ``kv_len_mask``: optional (B, Sk) float validity mask (nonzero = valid),
    per the shared mask contract in ``repro.kernels.ops``.
    """
    o, _, _ = _chunked_fwd(q, k, v, cfg, causal, chunk, q_offset, kv_len_mask)
    return o.astype(q.dtype)


def _cha_fwd(q, k, v, cfg, causal, chunk, q_offset, kv_len_mask=None):
    o, m_f, l_f = _chunked_fwd(q, k, v, cfg, causal, chunk, q_offset,
                               kv_len_mask)
    return o.astype(q.dtype), (q, k, v, kv_len_mask, o, m_f, l_f)


def _cha_bwd(cfg, causal, chunk, q_offset, res, do):
    """Flash-style backward: recompute Hyft probs per chunk from the saved
    row stats (single-pass, no online rescale), then the standard softmax
    attention gradients.  The softmax-VJP identity is applied to the *Hyft*
    probabilities — the paper's training mode, matrix-free."""
    q, k, v, kv_len_mask, o, m_f, l_f = res
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    g = Hq // Hkv
    nk = Sk // chunk
    scale = D ** -0.5
    qg = q.reshape(B, Hkv, g, Sq, D).astype(F32)
    dog = do.reshape(B, Hkv, g, Sq, D).astype(F32)
    og = o.reshape(B, Hkv, g, Sq, D).astype(F32)
    delta = jnp.sum(dog * og, axis=-1, keepdims=True)  # (B,Hkv,g,Sq,1)
    e_b, m_b = nm.lod_refloat(l_f, cfg.mant_bits)

    kc = k.reshape(B, Hkv, nk, chunk, D).transpose(2, 0, 1, 3, 4).astype(F32)
    vc = v.reshape(B, Hkv, nk, chunk, D).transpose(2, 0, 1, 3, 4).astype(F32)
    mc = _mask_chunks(kv_len_mask, B, nk, chunk)

    def probs(j, kt, mt):
        z = jnp.einsum("bhgqd,bhkd->bhgqk", qg * scale, kt)
        if causal:
            qi = q_offset + jax.lax.broadcasted_iota(I32, (Sq, chunk), 0)
            ki = jax.lax.broadcasted_iota(I32, (Sq, chunk), 1) + j * chunk
            z = jnp.where((qi >= ki)[None, None, None], z, NEG_BIG)
        if mt is not None:
            z = jnp.where(_mask_bcast(mt) > 0, z, NEG_BIG)
        z_raw = nm.fp2fx(z, cfg.frac_bits, cfg.total_bits)
        e, m = nm.exp_unit(z_raw - m_f, cfg.frac_bits, cfg.mant_bits)
        return nm.log_div(e, m, e_b, m_b, cfg.mant_bits)  # broadcast over chunk

    def body(dq, xs):
        j, kt, vt, mt = xs
        p = probs(j, kt, mt)  # (B,Hkv,g,Sq,chunk)
        dv = jnp.einsum("bhgqk,bhgqd->bhkd", p, dog)
        dp = jnp.einsum("bhgqd,bhkd->bhgqk", dog, vt)
        ds = p * (dp - delta)
        dq = dq + jnp.einsum("bhgqk,bhkd->bhgqd", ds, kt) * scale
        dk = jnp.einsum("bhgqk,bhgqd->bhkd", ds, qg) * scale
        return dq, (dk, dv)

    dq0 = jnp.zeros((B, Hkv, g, Sq, D), F32)
    dq, (dks, dvs) = jax.lax.scan(body, dq0, (jnp.arange(nk), kc, vc, mc))
    dk = dks.transpose(1, 2, 0, 3, 4).reshape(B, Hkv, Sk, D)
    dv = dvs.transpose(1, 2, 0, 3, 4).reshape(B, Hkv, Sk, D)
    dmask = None if kv_len_mask is None else jnp.zeros_like(kv_len_mask)
    return (dq.reshape(B, Hq, Sq, D).astype(q.dtype), dk.astype(k.dtype),
            dv.astype(v.dtype), dmask)


chunked_hyft_attention.defvjp(_cha_fwd, _cha_bwd)


# --------------------------------------------------------------------------
# mode selection + decode
# --------------------------------------------------------------------------


def attention_fwd(q, k, v, cfg, *, causal=True, q_offset=0, kv_len_mask=None):
    """Dispatch on cfg.attn_mode; falls back to unfused for non-Hyft impls.

    All three modes honor the shared mask contract (``repro.kernels.ops``):
    ``kv_len_mask`` (B, Sk) marks valid KV positions, so decode and serving
    stay on the fused/online paths instead of dropping to unfused.  The only
    remaining fallbacks are non-Hyft softmax impls, a traced ``q_offset``
    (the fused paths need it static for the causal mask), and a KV length
    the chunk size doesn't divide (chunked mode only).
    """
    hcfg = hyft_config_for(cfg.softmax_impl)
    mode = getattr(cfg, "attn_mode", "unfused")
    if hcfg is not None and isinstance(q_offset, int):
        from repro.kernels import ops
        maskf = ops.as_mask_f(kv_len_mask)
        if mode == "chunked":
            chunk = min(getattr(cfg, "attn_chunk", 512), k.shape[2])
            if k.shape[2] % chunk == 0:
                return chunked_hyft_attention(q, k, v, hcfg, causal, chunk,
                                              q_offset, maskf)
        if mode == "kernel":
            return ops.hyft_attention(
                q, k, v, hcfg, causal=causal, q_offset=q_offset,
                kv_len_mask=maskf).astype(q.dtype)
    return unfused_attention(q, k, v, cfg.softmax_impl, causal=causal,
                             q_offset=q_offset, kv_len_mask=kv_len_mask)


# --------------------------------------------------------------------------
# sequence-parallel decode: the Hyft L1/L2 tree across devices
# --------------------------------------------------------------------------


def sp_decode_attention(q, k_shard, v_shard, valid_mask, cfg: HyftConfig,
                        axis_name: str):
    """Per-shard body (call inside shard_map; KV cache sharded on seq axis).

    q: (B,Hq,1,D) replicated over ``axis_name``; k/v_shard: (B,Hkv,Ss,D)
    local slice; valid_mask: (B,Ss) bool local.  L1 = local Hyft stages 1-2;
    L2 = pmax of the fixed-point max + psum of rescaled fixed sums / accs —
    the paper's two-layer Hyft tree with ICI as the second layer.
    """
    B, Hq, _, D = q.shape
    Hkv = k_shard.shape[1]
    g = Hq // Hkv
    qg = q.reshape(B, Hkv, g, 1, D).astype(F32) * (D ** -0.5)
    z = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k_shard.astype(F32))
    z = jnp.where(valid_mask[:, None, None, None, :], z, NEG_BIG)
    # L1: local fixed-point max + exp/sum
    z_raw = nm.fp2fx(z, cfg.frac_bits, cfg.total_bits)
    m_loc = jnp.max(z_raw, axis=-1, keepdims=True)
    # L2a: global max (integer pmax over ICI)
    m_glob = jax.lax.pmax(m_loc, axis_name)
    e, m = nm.exp_unit(z_raw - m_glob, cfg.frac_bits, cfg.mant_bits)
    addend = nm.expfloat_to_fx(e, m, cfg.mant_bits, cfg.acc_bits)
    l_loc = jnp.sum(addend, axis=-1, keepdims=True)
    p = ((1 << cfg.mant_bits) + m).astype(F32) * nm.pow2_float(e - cfg.mant_bits)
    acc_loc = jnp.einsum("bhgqk,bhkd->bhgqd", p, v_shard.astype(F32))
    # L2b: global fixed-point sum + acc reduce
    l_glob = jax.lax.psum(l_loc, axis_name)
    acc_glob = jax.lax.psum(acc_loc, axis_name)
    out = _hyft_finalize(acc_glob, l_glob, cfg)
    return out.reshape(B, Hq, 1, D)


# --------------------------------------------------------------------------
# KV cache (dense or FP2FX-quantized int8)
# --------------------------------------------------------------------------
#
# ``cache_dtype="fp2fx8"`` stores K/V as int8 FP2FX raws with an fp32
# per-(head, position) scale — the paper's format-conversion idea applied to
# the KV stream decode actually spends its bandwidth on.  Writes run the
# FP2FX converter (``nm.fp2fx`` at total_bits=8); the split-K decode kernel
# fuses dequantization into its K/V loads, so HBM traffic stays int8.

FP2FX8 = "fp2fx8"
_FP2FX8_FRAC = 7  # int8 raw at 7 fractional bits; the scale folds in 2**-7


def is_fp2fx8(dtype) -> bool:
    return str(dtype) == FP2FX8


def cache_storage_dtype(dtype):
    """jnp dtype for non-attention cache buffers (SSM state, encoder memory)
    when the attention cache may be the symbolic "fp2fx8" format."""
    return jnp.dtype(jnp.float32 if is_fp2fx8(dtype) else dtype)


def fp2fx8_quantize(x):
    """(..., D) float -> (int8 raw, fp32 scale over the last axis).

    Per-(head, position) amax scale maps the row into [-127/128, 127/128];
    the FP2FX converter (round-to-nearest, saturating) then emits the int8
    raw.  Dequantization is ``raw * scale`` with the 2**-frac folded in.
    """
    amax = jnp.max(jnp.abs(x.astype(F32)), axis=-1)
    s = jnp.maximum(amax, 1e-30) * F32(128.0 / 127.0)
    raw = nm.fp2fx(x.astype(F32) / s[..., None], _FP2FX8_FRAC, 8)
    return raw.astype(jnp.int8), s * F32(2.0 ** -_FP2FX8_FRAC)


def fp2fx8_dequantize(raw, scale):
    return raw.astype(F32) * scale[..., None]


def cache_is_quantized(cache) -> bool:
    return "k_scale" in cache


def cache_kv(cache):
    """(k, v) as float arrays — dequantizes the fp2fx8 layout on demand (the
    unfused/chunked fallbacks; the split-K kernel reads the raws directly)."""
    if cache_is_quantized(cache):
        return (fp2fx8_dequantize(cache["k"], cache["k_scale"]),
                fp2fx8_dequantize(cache["v"], cache["v_scale"]))
    return cache["k"], cache["v"]


def cache_init(cfg, batch, max_len, dtype) -> dict[str, Any]:
    shape = (batch, cfg.n_kv_heads, max_len, cfg.d_head)
    if is_fp2fx8(dtype):
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(shape[:3], F32),
                "v_scale": jnp.zeros(shape[:3], F32)}
    dtype = jnp.dtype(dtype)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_update(cache, k_new, v_new, pos):
    """k_new/v_new: (B,Hkv,S_new,D); pos: scalar write offset."""
    if cache_is_quantized(cache):
        kr, ks = fp2fx8_quantize(k_new)
        vr, vs = fp2fx8_quantize(v_new)
        return {
            "k": jax.lax.dynamic_update_slice(cache["k"], kr, (0, 0, pos, 0)),
            "v": jax.lax.dynamic_update_slice(cache["v"], vr, (0, 0, pos, 0)),
            "k_scale": jax.lax.dynamic_update_slice(
                cache["k_scale"], ks, (0, 0, pos)),
            "v_scale": jax.lax.dynamic_update_slice(
                cache["v_scale"], vs, (0, 0, pos)),
        }
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, 0, pos, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, 0, pos, 0))
    return {"k": k, "v": v}


def cache_update_ragged(cache, k_new, v_new, pos_b, write_mask=None):
    """Per-row cache scatter: row ``b``'s (Hkv, 1, D) K/V lands at its own
    position ``pos_b[b]`` — the slot-pool decode step, where every slot sits
    at a different sequence length.

    ``write_mask`` (B,) bool gates the write per row: a False row re-writes
    its *old* cache content at ``pos_b[b]`` (an exact no-op), so finished
    (EOS'd / drained) slots in the continuous-batching pool stop mutating
    their cache while the rest of the pool keeps decoding.
    """
    B = k_new.shape[0]
    gate = jnp.ones((B,), bool) if write_mask is None else write_mask

    def upd(buf, new, pos, g):
        # buf (Hkv, L[, D]); position axis is axis 1 for values and scales
        start = (0, pos) + (0,) * (buf.ndim - 2)
        old = jax.lax.dynamic_slice(buf, start, new.shape)
        new = jnp.where(g, new.astype(buf.dtype), old)
        return jax.lax.dynamic_update_slice(buf, new, start)

    up = jax.vmap(upd, in_axes=(0, 0, 0, 0))
    if cache_is_quantized(cache):
        kr, ks = fp2fx8_quantize(k_new)
        vr, vs = fp2fx8_quantize(v_new)
        return {"k": up(cache["k"], kr, pos_b, gate),
                "v": up(cache["v"], vr, pos_b, gate),
                "k_scale": up(cache["k_scale"], ks, pos_b, gate),
                "v_scale": up(cache["v_scale"], vs, pos_b, gate)}
    return {"k": up(cache["k"], k_new, pos_b, gate),
            "v": up(cache["v"], v_new, pos_b, gate)}


def cache_update_block_ragged(cache, k_new, v_new, pos_b, n_valid,
                              write_mask=None):
    """Multi-token ragged scatter: token ``j`` of row ``b`` lands at
    ``pos_b[b] + j`` — the speculative-decode verify write, where the
    [last_token, draft...] chunk enters the cache BEFORE attention exactly
    like the one-token decode step's write-then-attend.

    ``n_valid`` (B,) bounds each row's real tokens (draft lengths are
    ragged across the batch); lanes with ``j >= n_valid[b]`` — and whole
    rows with ``write_mask[b]`` False — rewrite their *old* content at a
    clamped position, so padded drafts neither corrupt the cache nor shift
    a ``dynamic_update_slice`` at the cache edge.  Token-by-token through
    ``cache_update_ragged`` so the fp2fx8 per-(head, position) scales are
    bitwise those of sequential decode writes.
    """
    B, _, S, _ = k_new.shape
    L = cache["k"].shape[2]
    base = jnp.ones((B,), bool) if write_mask is None else write_mask
    nv = jnp.asarray(n_valid, I32)
    for j in range(S):
        gate = base & (j < nv) & (pos_b + j < L)
        pj = jnp.clip(pos_b + j, 0, L - 1)
        cache = cache_update_ragged(cache, k_new[:, :, j:j + 1],
                                    v_new[:, :, j:j + 1], pj, gate)
    return cache


# --------------------------------------------------------------------------
# paged KV cache (block-table indirection over a global page pool)
# --------------------------------------------------------------------------
#
# ``kv_layout="paged"`` (DESIGN.md §10) replaces the per-slot dense stripe
# with one global pool of fixed-size pages — (n_pages + 1, Hkv, page_size, D)
# per layer, dense or fp2fx8 — plus a per-sequence block table mapping
# virtual KV block j to a physical page.  Page 0 is the reserved null page
# (``repro.serve.kvpool.NULL_PAGE``): masked writes are *redirected* at it
# instead of gated, so the token scatter never needs a gather-then-rewrite
# and two rows can never race on a live page (distinct slots own distinct
# unshared tail pages; shared prefix pages are read-only by construction).


def paged_cache_init(cfg, n_pages, page_size, dtype) -> dict[str, Any]:
    """One layer's page pool: ``n_pages`` usable pages + the null page 0."""
    shape = (n_pages + 1, cfg.n_kv_heads, page_size, cfg.d_head)
    if is_fp2fx8(dtype):
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(shape[:3], F32),
                "v_scale": jnp.zeros(shape[:3], F32)}
    dtype = jnp.dtype(dtype)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_update_paged(cache, k_new, v_new, pos_b, block_tables,
                       write_mask=None):
    """Per-row paged scatter: row ``b``'s (Hkv, 1, D) K/V lands in physical
    page ``block_tables[b, pos_b[b] // ps]`` at offset ``pos_b[b] % ps``.

    ``write_mask`` (B,) bool redirects masked rows to the null page — their
    write happens but lands in the sink, so finished slots stop mutating
    live pages without any gather.
    """
    ps = cache["k"].shape[2]
    blk = pos_b // ps
    off = pos_b % ps
    page = jnp.take_along_axis(block_tables, blk[:, None], axis=1)[:, 0]
    if write_mask is not None:
        page = jnp.where(write_mask, page, 0)

    def scat(pool, new):  # new (B, Hkv[, D])
        return pool.at[page, :, off].set(new.astype(pool.dtype))

    if cache_is_quantized(cache):
        kr, ks = fp2fx8_quantize(k_new)
        vr, vs = fp2fx8_quantize(v_new)
        return {"k": scat(cache["k"], kr[:, :, 0]),
                "v": scat(cache["v"], vr[:, :, 0]),
                "k_scale": scat(cache["k_scale"], ks[:, :, 0]),
                "v_scale": scat(cache["v_scale"], vs[:, :, 0])}
    return {"k": scat(cache["k"], k_new[:, :, 0]),
            "v": scat(cache["v"], v_new[:, :, 0])}


def cache_update_block_paged(cache, k_new, v_new, pos_b, block_tables,
                             n_valid, write_mask=None):
    """Paged twin of ``cache_update_block_ragged``: token ``j`` of row ``b``
    scatters through the block table at virtual position ``pos_b[b] + j``.
    Lanes past ``n_valid[b]``, rows with ``write_mask`` False, and lanes
    past the table's virtual extent are redirected to the null page — the
    usual paged "no write" that can never race a live page.
    """
    B, _, S, _ = k_new.shape
    Lv = block_tables.shape[1] * cache["k"].shape[2]
    base = jnp.ones((B,), bool) if write_mask is None else write_mask
    nv = jnp.asarray(n_valid, I32)
    for j in range(S):
        gate = base & (j < nv) & (pos_b + j < Lv)
        pj = jnp.clip(pos_b + j, 0, Lv - 1)
        cache = cache_update_paged(cache, k_new[:, :, j:j + 1],
                                   v_new[:, :, j:j + 1], pj, block_tables,
                                   gate)
    return cache


def paged_gather_kv(cache, block_tables):
    """Materialize the virtual dense (B, Hkv, nb * ps, D) float K/V of each
    sequence from its block table — the unfused/chunked fallback; the paged
    split-K kernel gathers via its index maps instead."""

    def flat(pool):  # (B, nb, Hkv, ps[, D]) -> (B, Hkv, nb * ps[, D])
        x = jnp.moveaxis(jnp.take(pool, block_tables, axis=0), 2, 1)
        return x.reshape(x.shape[0], x.shape[1], -1, *x.shape[4:])

    if cache_is_quantized(cache):
        return (fp2fx8_dequantize(flat(cache["k"]), flat(cache["k_scale"])),
                fp2fx8_dequantize(flat(cache["v"]), flat(cache["v_scale"])))
    return flat(cache["k"]), flat(cache["v"])


def decode_attention_paged(q, cache, block_tables, cfg, *, kv_len_mask=None):
    """Sq=1 attention over a paged KV pool — the paged serving fast path.

    With a Hyft softmax and ``attn_mode="kernel"`` this dispatches to the
    block-table split-K kernel (pages gathered by scalar-prefetched index
    maps, fp2fx8 dequant fused into the page loads); every other combination
    materializes the virtual dense K/V and falls through to the regular
    dispatch, so all three attention modes serve the paged layout.
    """
    hcfg = hyft_config_for(cfg.softmax_impl)
    mode = getattr(cfg, "attn_mode", "unfused")
    if hcfg is not None and mode == "kernel" and q.shape[2] == 1:
        from repro.kernels import ops
        return ops.hyft_paged_decode_attention(
            q, cache["k"], cache["v"], block_tables, hcfg,
            kv_len_mask=ops.as_mask_f(kv_len_mask),
            k_scale=cache.get("k_scale"),
            v_scale=cache.get("v_scale")).astype(q.dtype)
    k, v = paged_gather_kv(cache, block_tables)
    return attention_fwd(q, k, v, cfg, causal=False, kv_len_mask=kv_len_mask)


def decode_attention(q, cache, cfg, *, kv_len_mask=None):
    """Sq=1 attention over the KV cache — the serving fast path.

    With a Hyft softmax and ``attn_mode="kernel"`` this dispatches to the
    split-K fused decode kernel (``repro.kernels.ops.hyft_decode_attention``),
    reading the fp2fx8 cache raws directly (dequant fused into the K/V
    loads).  Every other combination dequantizes once and falls through to
    the regular mode dispatch.
    """
    hcfg = hyft_config_for(cfg.softmax_impl)
    mode = getattr(cfg, "attn_mode", "unfused")
    if hcfg is not None and mode == "kernel" and q.shape[2] == 1:
        from repro.kernels import ops
        return ops.hyft_decode_attention(
            q, cache["k"], cache["v"], hcfg,
            kv_len_mask=ops.as_mask_f(kv_len_mask),
            k_scale=cache.get("k_scale"),
            v_scale=cache.get("v_scale")).astype(q.dtype)
    k, v = cache_kv(cache)
    return attention_fwd(q, k, v, cfg, causal=False, kv_len_mask=kv_len_mask)


# --------------------------------------------------------------------------
# chunked attend-at-offset (Sq = chunk, per-token causal frontier) — the
# attention entry behind model.prefill_chunk: prefill chunks, prefix-hit
# suffixes, and speculative-decode verify all land here (DESIGN.md §12)
# --------------------------------------------------------------------------


def _verify_unfused(q, k, v, softmax_impl: str, kv_pos_mask):
    """Unfused reference with a per-query-token (B, Sq, Sk) mask — the same
    arithmetic as ``unfused_attention``'s masked decode, one row per draft
    token, so greedy verify matches greedy sequential decode per row."""
    B, Hq, Sq, D = q.shape
    Hkv = k.shape[1]
    g = Hq // Hkv
    qg = q.reshape(B, Hkv, g, Sq, D)
    z = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(F32),
                   k.astype(F32)) * (D ** -0.5)
    z = jnp.where(kv_pos_mask[:, None, None, :, :] > 0, z, NEG_BIG)
    p = get_softmax(softmax_impl)(z).astype(F32)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(F32))
    return o.reshape(B, Hq, Sq, D).astype(q.dtype)


def verify_attention(q, cache, cfg, *, kv_pos_mask, block_tables=None):
    """Attend a token chunk at per-row offsets against the serving cache:
    ``q`` carries a chunk of Sq already-written tokens per row and
    ``kv_pos_mask`` (B, Sq, Lk) each token's causal frontier (``kv_index
    <= pos + t``), so every chunk token sees exactly the KV a sequential
    decode step would have.  This is ``model.prefill_chunk``'s attention
    (DESIGN.md §12): prompt-chunk prefill, prefix-hit suffixes, and
    speculative-decode verify (Sq = draft_k + 1) are all this one call.

    With a Hyft softmax and ``attn_mode="kernel"`` this is the split-K
    verify kernel (dense stripes or — with ``block_tables`` — the paged
    pool, fp2fx8 dequant fused into the loads); chunked mode runs the
    online-Hyft scan under the same per-row mask; everything else falls to
    the unfused reference.  Each mode mirrors its decode counterpart's
    arithmetic, which is what makes greedy speculative decode
    token-for-token identical to vanilla greedy decode.
    """
    hcfg = hyft_config_for(cfg.softmax_impl)
    mode = getattr(cfg, "attn_mode", "unfused")
    if hcfg is not None and mode == "kernel":
        from repro.kernels import ops
        return ops.hyft_verify_attention(
            q, cache["k"], cache["v"], kv_pos_mask, hcfg,
            block_tables=block_tables,
            k_scale=cache.get("k_scale"),
            v_scale=cache.get("v_scale")).astype(q.dtype)
    if block_tables is not None:
        k, v = paged_gather_kv(cache, block_tables)
    else:
        k, v = cache_kv(cache)
    if hcfg is not None and mode == "chunked":
        chunk = min(getattr(cfg, "attn_chunk", 512), k.shape[2])
        if k.shape[2] % chunk == 0:
            from repro.kernels import ops
            return chunked_hyft_attention(
                q, k, v, hcfg, False, chunk, 0,
                ops.as_mask_f(kv_pos_mask)).astype(q.dtype)
    return _verify_unfused(q, k, v, cfg.softmax_impl, kv_pos_mask)
