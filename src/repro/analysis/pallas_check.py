"""Pallas tile checker: prove BlockSpec index maps in-bounds over the grid.

Every kernel in the registry (fused fwd, the two bwd kernels, split-K
decode, paged decode, spec verify -- dense, fp2fx8, and paged layouts) is
traced to a jaxpr at smoke shapes; for each ``pallas_call`` eqn the checker
abstractly evaluates every BlockSpec index map at *every* grid point and
proves, per dimension:

``tile.out-of-bounds``  ``0 <= idx*bs`` and ``idx*bs + bs <= shape`` -- the
                        tile lies inside the operand for all grid points.
``tile.unaligned``      the operand dimension is a multiple of the block
                        size (this repo's kernels pre-pad instead of
                        masking tails, so a ragged tail is always a bug).
``tile.bad-dtype``      ref dtypes match the declared cache format (int8
                        raws + fp32 scales for fp2fx8; fp32 otherwise).

Paged kernels gather pages through scalar-prefetched block tables; their
index maps are evaluated under each entry's ``scalar_variants`` -- the
all-zeros table and the all-max (``n_pages - 1``) table, the extreme points
of the monotone gather, which bound every realizable table in between.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax._src import core as jcore
from jax._src.state import discharge

from repro.analysis.common import Finding, subjaxprs
from repro.core.hyft import HYFT16

F32, I32, I8 = jnp.float32, jnp.int32, jnp.int8


@dataclasses.dataclass
class KernelEntry:
    """One kernel to check.

    ``make`` returns ``(fn, args)`` -- a traceable callable (statics closed
    over) and smoke-size operands.  ``scalar_variants`` are tuples of arrays
    fed to scalar-prefetch index maps after the grid indices (empty tuple =
    kernel has no scalar prefetch).  ``expect_dtypes`` maps *input operand
    position* (after scalar-prefetch operands) to the dtype the declared
    cache format requires for that ref.
    """
    name: str
    make: Callable[[], tuple[Callable, tuple]]
    scalar_variants: tuple[tuple, ...] = ((),)
    expect_dtypes: dict[int, str] = dataclasses.field(default_factory=dict)


def _find_pallas_eqns(jaxpr: jcore.Jaxpr) -> list[jcore.JaxprEqn]:
    out = []
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            out.append(eqn)
        for sub in subjaxprs(eqn):
            out += _find_pallas_eqns(sub)
    return out


def _block_sizes(block_shape) -> list[int]:
    # squeezed/mapped dims appear as non-int sentinels; they index a single
    # element, i.e. an effective block extent of 1
    return [int(b) if isinstance(b, (int, np.integer)) else 1
            for b in block_shape]


def check_entry(entry: KernelEntry) -> list[Finding]:
    fn, args = entry.make()
    closed = jax.make_jaxpr(fn)(*args)
    eqns = _find_pallas_eqns(closed.jaxpr)
    if not eqns:
        return [Finding("pallas", "registry.no-kernel", entry.name,
                        "entry traced to a jaxpr with no pallas_call")]
    findings: list[Finding] = []
    for ei, eqn in enumerate(eqns):
        gm = eqn.params["grid_mapping"]
        where = f"{entry.name}#call{ei}"
        grid = tuple(gm.grid)
        if not all(isinstance(g, (int, np.integer)) for g in grid):
            findings.append(Finding(
                "pallas", "tile.dynamic-grid", where,
                f"grid {grid} is not fully static -- bounds unprovable"))
            continue
        n_scalar = getattr(gm, "num_index_operands", 0)
        for variant in entry.scalar_variants:
            if n_scalar and len(variant) != n_scalar:
                findings.append(Finding(
                    "pallas", "registry.bad-variant", where,
                    f"kernel prefetches {n_scalar} scalar operand(s) but the "
                    f"entry's variant supplies {len(variant)}"))
                continue
            findings += _check_mappings(gm, grid, variant, entry, where)
            if findings and len(findings) > 64:
                return findings  # a broken map floods; the first page suffices
    return findings


def _check_mappings(gm, grid, scalar_args, entry: KernelEntry,
                    where: str) -> list[Finding]:
    findings: list[Finding] = []
    for bi, bm in enumerate(gm.block_mappings):
        # block_mappings cover blocked operands only -- scalar-prefetch
        # operands have no BlockSpec, so ``bi`` aligns with the entry's
        # operand positions directly
        sds = bm.array_shape_dtype
        shape, dtype = tuple(sds.shape), str(sds.dtype)
        bs = _block_sizes(bm.block_shape)
        opos = bi
        want = entry.expect_dtypes.get(opos)
        if want is not None and dtype != want:
            findings.append(Finding(
                "pallas", "tile.bad-dtype", f"{where} operand {opos}",
                f"ref dtype {dtype} but the declared cache format requires "
                f"{want}"))
        for d, (sz, b) in enumerate(zip(shape, bs)):
            if sz % b != 0:
                findings.append(Finding(
                    "pallas", "tile.unaligned", f"{where} operand {opos}",
                    f"dim {d}: shape {sz} not a multiple of block {b} -- "
                    f"this repo pre-pads, a ragged tail is unmasked"))
        cj = bm.index_map_jaxpr
        # scalar-prefetch operands are Refs inside the index-map jaxpr;
        # discharging turns the `get` gathers into pure indexing so the map
        # is evaluable on plain arrays (appends final ref values as extra
        # outputs, sliced off below)
        n_out = len(cj.jaxpr.outvars)
        dis, dconsts = discharge.discharge_state(cj.jaxpr, cj.consts)
        for point in np.ndindex(*grid):
            idx = jcore.eval_jaxpr(dis, dconsts,
                                   *[jnp.int32(p) for p in point],
                                   *scalar_args)[:n_out]
            for d, (i, b, sz) in enumerate(zip(idx, bs, shape)):
                start = int(i) * b
                if start < 0 or start + b > max(sz, b):
                    findings.append(Finding(
                        "pallas", "tile.out-of-bounds",
                        f"{where} operand {opos}",
                        f"grid point {tuple(point)} dim {d}: block index "
                        f"{int(i)} * block {b} = [{start}, {start + b}) "
                        f"outside operand extent {sz}"))
                    break
            else:
                continue
            break  # one OOB point per mapping is enough signal
    return findings


# -- the kernel registry ----------------------------------------------------


def default_registry() -> list[KernelEntry]:
    from repro.kernels.flash_attention import (
        flash_hyft_attention, flash_hyft_decode, flash_hyft_decode_paged,
        flash_hyft_verify)
    from repro.kernels.hyft_softmax import (
        hyft_softmax_bwd_kernel, hyft_softmax_fwd_kernel)

    cfg = HYFT16
    key = jax.random.PRNGKey(0)
    B, Hq, Hkv, D = 2, 4, 2, 16
    g = Hq // Hkv

    def rnd(shape, dtype=F32, k=0):
        if dtype == I8:
            return jax.random.randint(jax.random.fold_in(key, k), shape,
                                      -127, 128, I32).astype(I8)
        return jax.random.normal(jax.random.fold_in(key, k), shape, dtype)

    entries: list[KernelEntry] = []

    # ---- standalone softmax fwd/bwd (row-tiled) ----
    entries.append(KernelEntry(
        "softmax_fwd",
        lambda: (lambda z: hyft_softmax_fwd_kernel(z, cfg), (rnd((24, 64)),))))
    entries.append(KernelEntry(
        "softmax_bwd",
        lambda: (lambda s, dy: hyft_softmax_bwd_kernel(s, dy, cfg),
                 (jax.nn.softmax(rnd((24, 64))), rnd((24, 64), k=1)))))

    # ---- fused flash fwd + the two bwd kernels (dq and dk/dv) ----
    def mk_flash_fwd():
        q, k, v = rnd((B, Hq, 32, D)), rnd((B, Hkv, 32, D), k=1), \
            rnd((B, Hkv, 32, D), k=2)
        fn = lambda q, k, v: flash_hyft_attention(
            q, k, v, cfg, block_q=16, block_k=16)
        return fn, (q, k, v)
    entries.append(KernelEntry("flash_fwd", mk_flash_fwd))

    def mk_flash_bwd():
        q, k, v = rnd((B, Hq, 32, D)), rnd((B, Hkv, 32, D), k=1), \
            rnd((B, Hkv, 32, D), k=2)
        fn = jax.grad(lambda q, k, v: flash_hyft_attention(
            q, k, v, cfg, block_q=16, block_k=16).sum(), argnums=(0, 1, 2))
        return fn, (q, k, v)
    entries.append(KernelEntry("flash_bwd", mk_flash_bwd))

    # ---- split-K decode, dense fp32 and fp2fx8 (int8 + scales) ----
    Sk = 48  # deliberately not lane-aligned: exercises the pad path
    def mk_splitk():
        q, k, v = rnd((B, Hq, 1, D)), rnd((B, Hkv, Sk, D), k=1), \
            rnd((B, Hkv, Sk, D), k=2)
        fn = lambda q, k, v: flash_hyft_decode(q, k, v, cfg, block_k=128)
        return fn, (q, k, v)
    entries.append(KernelEntry("splitk_decode[float32]", mk_splitk))

    def mk_splitk_q():
        q = rnd((B, Hq, 1, D))
        k, v = rnd((B, Hkv, Sk, D), I8, 1), rnd((B, Hkv, Sk, D), I8, 2)
        ks, vs = rnd((B, Hkv, Sk), k=3), rnd((B, Hkv, Sk), k=4)
        fn = lambda q, k, v, ks, vs: flash_hyft_decode(
            q, k, v, cfg, block_k=128, k_scale=ks, v_scale=vs)
        return fn, (q, k, v, ks, vs)
    entries.append(KernelEntry(
        "splitk_decode[fp2fx8]", mk_splitk_q,
        expect_dtypes={1: "int8", 2: "int8", 3: "float32", 4: "float32"}))

    # ---- paged decode: block-table gather via scalar prefetch ----
    n_pages, ps, nb = 6, 8, 3
    bt_variants = (
        (jnp.zeros((B, nb), I32),),
        (jnp.full((B, nb), n_pages - 1, I32),),
    )

    def mk_paged(qz: bool):
        def make():
            q = rnd((B, Hq, 1, D))
            kp = rnd((n_pages, Hkv, ps, D), I8 if qz else F32, 1)
            vp = rnd((n_pages, Hkv, ps, D), I8 if qz else F32, 2)
            bt = jnp.arange(B * nb, dtype=I32).reshape(B, nb) % n_pages
            if qz:
                ks, vs = rnd((n_pages, Hkv, ps), k=3), \
                    rnd((n_pages, Hkv, ps), k=4)
                fn = lambda q, kp, vp, bt: flash_hyft_decode_paged(
                    q, kp, vp, bt, cfg, k_scale=ks, v_scale=vs)
            else:
                fn = lambda q, kp, vp, bt: flash_hyft_decode_paged(
                    q, kp, vp, bt, cfg)
            return fn, (q, kp, vp, bt)
        return make
    entries.append(KernelEntry("paged_decode[float32]", mk_paged(False),
                               scalar_variants=bt_variants))
    entries.append(KernelEntry(
        "paged_decode[fp2fx8]", mk_paged(True), scalar_variants=bt_variants,
        expect_dtypes={1: "int8", 2: "int8", 3: "float32", 4: "float32"}))

    # ---- spec-verify chunk kernel, dense and paged ----
    Sq = 4

    def mk_verify_dense():
        q = rnd((B, Hq, Sq, D))
        k, v = rnd((B, Hkv, Sk, D), k=1), rnd((B, Hkv, Sk, D), k=2)
        mask = jnp.ones((B, Sq, Sk), F32)
        fn = lambda q, k, v, m: flash_hyft_verify(q, k, v, m, cfg,
                                                  block_k=128)
        return fn, (q, k, v, mask)
    entries.append(KernelEntry("verify[dense]", mk_verify_dense))

    def mk_verify_paged():
        q = rnd((B, Hq, Sq, D))
        kp = rnd((n_pages, Hkv, ps, D), I8, 1)
        vp = rnd((n_pages, Hkv, ps, D), I8, 2)
        ks, vs = rnd((n_pages, Hkv, ps), k=3), rnd((n_pages, Hkv, ps), k=4)
        bt = jnp.arange(B * nb, dtype=I32).reshape(B, nb) % n_pages
        mask = jnp.ones((B, Sq, nb * ps), F32)
        fn = lambda q, kp, vp, bt, m: flash_hyft_verify(
            q, kp, vp, m, cfg, block_tables=bt, k_scale=ks, v_scale=vs)
        return fn, (q, kp, vp, bt, mask)
    entries.append(KernelEntry(
        "verify[paged,fp2fx8]", mk_verify_paged, scalar_variants=bt_variants,
        expect_dtypes={1: "int8", 2: "int8", 3: "float32", 4: "float32"}))

    return entries


def run(registry: list[KernelEntry] | None = None) -> list[Finding]:
    findings: list[Finding] = []
    for entry in registry if registry is not None else default_registry():
        findings += check_entry(entry)
    return findings
