"""Retrace guard: assert steady-state serving compiles nothing new.

PR 6 made every serving executable cache-keyed on (model config, normalized
serve config[, width/steps]) and ``prewarm()`` compile all bucket widths up
front; this module turns that discipline into a checkable invariant.  The
compile-log listener itself (regexes + ``jax_log_compiles`` logging
plumbing) lives in ``repro.obs.trace`` — the SAME machinery the runtime
tracer uses to stamp "compile" spans into a serve trace
(``compile_watch``); this module layers the budget/steady-state policy on
top, so a cache-key regression (a Python float smuggled into a jit static,
an un-normalized ServeConfig field, a shape that misses its bucket) fails
loudly instead of silently recompiling per request.

    with RetraceGuard() as g:
        pool.admit(reqs); pool.run()
    # raises RetraceError on exit if anything compiled

``max_compiles`` > 0 whitelists a known number of cold compiles (e.g. a
guard wrapped around a first call on purpose).
"""
from __future__ import annotations

from repro.obs.trace import COMPILE_RE, TRACE_RE, compile_watch

# back-compat aliases (pre-obs name for the shared regexes)
_TRACE_RE = TRACE_RE
_COMPILE_RE = COMPILE_RE


class RetraceError(AssertionError):
    """Steady-state code compiled something new."""


class RetraceGuard:
    """Context manager counting new traces/compiles inside its scope."""

    def __init__(self, max_compiles: int = 0):
        self.max_compiles = max_compiles
        self._watch = compile_watch()

    # results (inspectable mid-scope and after exit)
    @property
    def traces(self) -> list[str]:
        return list(self._watch.listener.traces)

    @property
    def compiles(self) -> list[str]:
        return list(self._watch.listener.compiles)

    def __enter__(self) -> "RetraceGuard":
        self._watch.__enter__()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self._watch.__exit__(exc_type, exc, tb)
        if exc_type is not None:
            return  # don't mask the real error
        compiles = self._watch.listener.compiles
        if len(compiles) > self.max_compiles:
            names = ", ".join(compiles)
            raise RetraceError(
                f"steady-state code triggered {len(compiles)} "
                f"XLA compilation(s) (allowed {self.max_compiles}): {names}")


# -- the steady-state serving scenario --------------------------------------


def serve_steady_state(scheduler: str = "continuous", n_requests: int = 8):
    """Run warmup admissions, then ``n_requests`` more through the same
    chunk buckets under a RetraceGuard.  Returns the guard (its ``compiles``
    empty on success); raises RetraceError if steady state compiled.

    The warmup batch walks every code path the guarded batch will take --
    prewarmed executables AND the small host-side jnp ops (first-token
    argmax, bucket padding) that also cache per shape -- so the guarded
    batch is genuinely steady-state.
    """
    import jax
    import numpy as np

    from repro.configs import get_config, smoke_config
    from repro.configs.base import ServeConfig
    from repro.models import build_model
    from repro.models.layers import unbox
    from repro.serve.scheduler import Request, SlotPoolEngine

    cfg = smoke_config(get_config("qwen2-1.5b")).with_(
        softmax_impl="hyft16", vocab=64)
    model = build_model(cfg)
    params = unbox(model.init(jax.random.PRNGKey(0)))
    scfg = ServeConfig(max_len=32, cache_dtype="float32",
                       scheduler=scheduler, n_slots=3, decode_burst=4,
                       prefill_chunk=4,
                       draft_k=3 if scheduler == "spec" else 4)
    eng = SlotPoolEngine(model, params, scfg)
    eng.prewarm(max_prompt_len=14)

    def batch(rid0: int, seed: int) -> list[Request]:
        rng = np.random.default_rng(seed)
        lengths = [4, 6, 9, 12, 5, 7, 10, 13][:n_requests]
        return [Request(rid=rid0 + i,
                        tokens=rng.integers(0, cfg.vocab, L).astype(np.int32),
                        max_new=3 + (i % 4))
                for i, L in enumerate(lengths)]

    eng.run(batch(0, 0))          # warmup: cold compiles land here
    with RetraceGuard() as guard:  # 8 admissions through warm buckets
        eng.run(batch(100, 1))
    return guard


def run(schedulers: tuple[str, ...] = ("continuous", "spec")):
    """check.py entry: returns Findings (empty = no steady-state compiles)."""
    from repro.analysis.common import Finding
    findings = []
    for sched in schedulers:
        try:
            serve_steady_state(sched)
        except RetraceError as e:
            findings.append(Finding("retrace", "steady-state-compile",
                                    f"serve[{sched}]", str(e)))
    return findings
