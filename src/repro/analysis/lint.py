"""Repo lint: AST rules for traced-code hygiene, specific to this codebase.

The rules run over every function the repo traces -- ``@jax.jit`` /
``functools.partial(jax.jit, ...)`` decorated functions, ``jax.jit(fn)``
call sites (lambda or named), and Pallas kernel bodies (functions passed to
``pl.pallas_call``, where positional params are refs and keyword-only params
are static by this repo's convention):

``traced-bool``     Python ``if``/``while``/``assert``/``bool()`` on a traced
                    value -- a trace-time error at best, a silently baked-in
                    constant at worst.  Static tests (``.shape``/``.ndim``/
                    ``.dtype``, ``len()``, ``is None``, ``isinstance``,
                    closed-over config) are exempt.
``host-call``       ``float()``/``int()``/``.item()``/``.tolist()`` or a
                    ``np.``/``numpy.`` call applied to traced values inside
                    traced code -- a host sync per call.
``prng.constant-seed``  ``jax.random.PRNGKey(<literal>)`` inside traced code:
                    a fresh constant key per trace means the same stream on
                    every invocation; keys must be threaded in.
``cache.not-donated``   a jit whose wrapped function takes a ``cache``/
                    ``pool`` positional arg must donate it
                    (``donate_argnums``/``donate_argnames``), or every call
                    copies the whole KV buffer.
``obs.untimed-hot-path``  a host-side ``for``/``while`` loop invoking a
                    jitted executable (a name assigned from ``jax.jit(...)``
                    or a ``build_*`` executable factory) outside any
                    ``with <tracer>.span(...)`` scope -- hot loops must be
                    observable (DESIGN.md §15); wrap the loop or the call in
                    a span, or waive with a cited reason.

Per-line waiver: a trailing ``# lint: allow(<rule>)`` comment suppresses
that rule on that line (cite the DESIGN.md #14 reason next to it).
"""
from __future__ import annotations

import ast
from pathlib import Path

from repro.analysis.common import Finding

CACHE_PARAM_NAMES = frozenset({"cache", "pool", "kv_cache", "paged_cache"})
_STATIC_ATTRS = frozenset({"shape", "ndim", "dtype", "size", "sharding",
                           "aval", "itemsize"})
_STATIC_CALLS = frozenset({"len", "isinstance", "hasattr", "getattr", "type",
                           "issubclass", "callable"})


# -- decorator / call-site classification -----------------------------------


def _dotted(node: ast.AST) -> str | None:
    """'jax.jit' for Attribute chains, 'jit' for bare Names."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_jit(node: ast.AST) -> bool:
    return _dotted(node) in ("jax.jit", "jit")


def _is_partial(node: ast.AST) -> bool:
    return _dotted(node) in ("functools.partial", "partial")


def _const_strs(node: ast.AST) -> set[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return {node.value}
    if isinstance(node, (ast.Tuple, ast.List)):
        return {e.value for e in node.elts
                if isinstance(e, ast.Constant) and isinstance(e.value, str)}
    return set()


def _const_ints(node: ast.AST) -> set[int]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return {node.value}
    if isinstance(node, (ast.Tuple, ast.List)):
        return {e.value for e in node.elts
                if isinstance(e, ast.Constant) and isinstance(e.value, int)}
    return set()


def _jit_spec(dec: ast.AST) -> dict | None:
    """Classify a decorator / call-head as a jit wrapper.

    Returns {static_names, static_nums, donate_nums, donate_names, donates}
    or None if the node is not a jit form.
    """
    if _is_jit(dec):
        return dict(static_names=set(), static_nums=set(),
                    donate_nums=set(), donate_names=set())
    if isinstance(dec, ast.Call) and (_is_jit(dec.func) or (
            _is_partial(dec.func) and dec.args and _is_jit(dec.args[0]))):
        kw = {k.arg: k.value for k in dec.keywords if k.arg}
        empty = ast.Tuple([], None)
        dn, dm = kw.get("donate_argnums", empty), kw.get("donate_argnames",
                                                         empty)
        # a donate kwarg that isn't a literal (e.g. ``(1,) if opts.donate
        # else ()``) is an explicit, condition-dependent decision -- the
        # dataflow-free lint must not second-guess it
        dynamic = any(not isinstance(v, (ast.Tuple, ast.List, ast.Constant))
                      for v in (dn, dm))
        return dict(
            static_names=_const_strs(kw.get("static_argnames", empty)),
            static_nums=_const_ints(kw.get("static_argnums", empty)),
            donate_nums=_const_ints(dn),
            donate_names=_const_strs(dm),
            donate_dynamic=dynamic,
        )
    return None


def _positional_params(args: ast.arguments) -> list[str]:
    return [a.arg for a in args.posonlyargs + args.args]


# -- expression classification ----------------------------------------------


def _has_dynamic(node: ast.AST, traced: frozenset[str]) -> bool:
    """True if the expression can depend on a traced runtime VALUE.

    Purely syntactic: a traced Name is dynamic unless it only feeds a
    statically-known projection (``.shape``, ``len()``, ``is None``, ...).
    Locals derived from traced values are not tracked (no dataflow) -- the
    lint under-approximates rather than false-positives.
    """
    if isinstance(node, ast.Name):
        return node.id in traced
    if isinstance(node, ast.Attribute) and node.attr in _STATIC_ATTRS:
        return False
    if isinstance(node, ast.Call):
        fn = node.func
        if isinstance(fn, ast.Name) and fn.id in _STATIC_CALLS:
            return False
    if isinstance(node, ast.Compare) and all(
            isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
        return False
    return any(_has_dynamic(c, traced) for c in ast.iter_child_nodes(node))


# -- per-function rule walker -----------------------------------------------


class _FnChecker(ast.NodeVisitor):
    def __init__(self, traced: frozenset[str], filename: str,
                 waived, out: list[Finding]):
        self.traced = traced
        self.filename = filename
        self.waived = waived
        self.out = out

    def _emit(self, rule: str, node: ast.AST, detail: str) -> None:
        if not self.waived(rule, node.lineno):
            self.out.append(Finding(
                "lint", rule, f"{self.filename}:{node.lineno}", detail))

    def _check_test(self, node: ast.AST, kind: str) -> None:
        if _has_dynamic(node, self.traced):
            self._emit("traced-bool", node,
                       f"`{kind}` on a traced value forces a Python bool at "
                       f"trace time; use lax.cond/jnp.where or a static test")

    def visit_If(self, node: ast.If) -> None:
        self._check_test(node.test, "if")
        self.generic_visit(node)

    def visit_While(self, node: ast.While) -> None:
        self._check_test(node.test, "while")
        self.generic_visit(node)

    def visit_Assert(self, node: ast.Assert) -> None:
        self._check_test(node.test, "assert")
        self.generic_visit(node)

    def visit_IfExp(self, node: ast.IfExp) -> None:
        self._check_test(node.test, "x if c else y")
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        fn = node.func
        if isinstance(fn, ast.Name) and fn.id == "bool" and any(
                _has_dynamic(a, self.traced) for a in node.args):
            self._emit("traced-bool", node, "`bool()` on a traced value")
        elif isinstance(fn, ast.Name) and fn.id in ("float", "int") and any(
                _has_dynamic(a, self.traced) for a in node.args):
            self._emit("host-call", node,
                       f"`{fn.id}()` on a traced value syncs to host")
        elif isinstance(fn, ast.Attribute):
            if fn.attr in ("item", "tolist") and \
                    _has_dynamic(fn.value, self.traced):
                self._emit("host-call", node,
                           f"`.{fn.attr}()` on a traced value syncs to host")
            else:
                root = fn.value
                while isinstance(root, ast.Attribute):
                    root = root.value
                if isinstance(root, ast.Name) and root.id in ("np", "numpy") \
                        and any(_has_dynamic(a, self.traced)
                                for a in node.args):
                    self._emit("host-call", node,
                               f"numpy call `{_dotted(fn)}` on traced values "
                               f"inside traced code")
            if _dotted(fn) in ("jax.random.PRNGKey", "random.PRNGKey") and \
                    node.args and isinstance(node.args[0], ast.Constant):
                self._emit("prng.constant-seed", node,
                           "constant PRNGKey inside traced code reuses the "
                           "same stream every call; thread the key in")
        self.generic_visit(node)


# -- obs.untimed-hot-path ---------------------------------------------------

# executable factories whose RESULT is not a jitted callable (a model object,
# a config, ...) -- calling these in a loop is not a hot-path dispatch
_JIT_BUILDER_DENY = frozenset({"build_model"})


def _jit_valued(node: ast.AST) -> bool:
    """True if the expression evaluates to a jitted executable: a
    ``jax.jit(...)`` call, a ``build_*(...)`` executable factory, or an
    IfExp choosing between such calls."""
    if isinstance(node, ast.IfExp):
        return _jit_valued(node.body) or _jit_valued(node.orelse)
    if isinstance(node, ast.Call):
        name = _dotted(node.func)
        if name is None:
            return False
        if name in ("jax.jit", "jit"):
            return True
        last = name.rsplit(".", 1)[-1]
        return last.startswith("build_") and last not in _JIT_BUILDER_DENY
    return False


def _collect_jit_targets(tree: ast.AST) -> tuple[set[str], set[str]]:
    """Names / attribute names assigned from jit-valued expressions anywhere
    in the module (``step = jax.jit(f)``, ``self._burst = build_burst(...)``)."""
    names: set[str] = set()
    attrs: set[str] = set()
    for n in ast.walk(tree):
        targets: list[ast.AST] = []
        if isinstance(n, ast.Assign) and _jit_valued(n.value):
            targets = list(n.targets)
        elif isinstance(n, ast.AnnAssign) and n.value is not None \
                and _jit_valued(n.value):
            targets = [n.target]
        for t in targets:
            if isinstance(t, ast.Name):
                names.add(t.id)
            elif isinstance(t, ast.Attribute):
                attrs.add(t.attr)
    return names, attrs


class _HotPathChecker(ast.NodeVisitor):
    """Flags calls to known jitted executables inside host loops that are
    not lexically under a ``with <something>.span(...)`` block."""

    def __init__(self, names: set[str], attrs: set[str], filename: str,
                 waived, out: list[Finding]):
        self.names = names
        self.attrs = attrs
        self.filename = filename
        self.waived = waived
        self.out = out
        self._in_span = False
        self._loop_depth = 0

    # a nested def runs later, outside any enclosing span/loop
    def visit_FunctionDef(self, node) -> None:
        prev = (self._in_span, self._loop_depth)
        self._in_span, self._loop_depth = False, 0
        self.generic_visit(node)
        self._in_span, self._loop_depth = prev

    visit_AsyncFunctionDef = visit_FunctionDef
    visit_Lambda = visit_FunctionDef

    def visit_With(self, node: ast.With) -> None:
        spanned = any(
            isinstance(it.context_expr, ast.Call)
            and isinstance(it.context_expr.func, ast.Attribute)
            and it.context_expr.func.attr == "span"
            for it in node.items)
        if spanned:
            prev, self._in_span = self._in_span, True
            self.generic_visit(node)
            self._in_span = prev
        else:
            self.generic_visit(node)

    def _visit_loop(self, node) -> None:
        self._loop_depth += 1
        self.generic_visit(node)
        self._loop_depth -= 1

    visit_For = _visit_loop
    visit_While = _visit_loop
    visit_AsyncFor = _visit_loop

    def visit_Call(self, node: ast.Call) -> None:
        if self._loop_depth and not self._in_span:
            fn = node.func
            hit = None
            if isinstance(fn, ast.Name) and fn.id in self.names:
                hit = fn.id
            elif isinstance(fn, ast.Attribute) and fn.attr in self.attrs:
                hit = fn.attr
            if hit is not None and not self.waived("obs.untimed-hot-path",
                                                   node.lineno):
                self.out.append(Finding(
                    "lint", "obs.untimed-hot-path",
                    f"{self.filename}:{node.lineno}",
                    f"host loop calls jitted executable `{hit}` outside any "
                    f"tracer span; wrap it in `with tracer.span(...)` "
                    f"(DESIGN.md §15) or waive with a reason"))
        self.generic_visit(node)


# -- module analysis --------------------------------------------------------


class _ModuleLinter:
    def __init__(self, src: str, filename: str):
        self.tree = ast.parse(src, filename=filename)
        self.filename = filename
        self.lines = src.splitlines()
        self.findings: list[Finding] = []
        self.defs: dict[str, ast.FunctionDef] = {}
        for n in ast.walk(self.tree):
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.defs.setdefault(n.name, n)

    def waived(self, rule: str, lineno: int) -> bool:
        if 1 <= lineno <= len(self.lines):
            line = self.lines[lineno - 1]
            return f"lint: allow({rule})" in line or \
                "lint: allow(all)" in line
        return False

    def _traced_params(self, args: ast.arguments, spec: dict) -> frozenset[str]:
        pos = _positional_params(args)
        traced = {p for i, p in enumerate(pos)
                  if p not in spec["static_names"]
                  and i not in spec["static_nums"] and p != "self"}
        if args.vararg is not None:
            traced.add(args.vararg.arg)
        return frozenset(traced)

    def _check_donation(self, args: ast.arguments, spec: dict,
                        node: ast.AST, label: str) -> None:
        if spec.get("donate_dynamic"):
            return
        pos = _positional_params(args)
        for i, p in enumerate(pos):
            if p in CACHE_PARAM_NAMES and i not in spec["donate_nums"] \
                    and p not in spec["donate_names"]:
                if not self.waived("cache.not-donated", node.lineno):
                    self.findings.append(Finding(
                        "lint", "cache.not-donated",
                        f"{self.filename}:{node.lineno}",
                        f"{label}: jit threads `{p}` (positional arg {i}) "
                        f"without donating it -- every call copies the "
                        f"buffer"))

    def _lint_traced_fn(self, body_node: ast.AST,
                        traced: frozenset[str]) -> None:
        checker = _FnChecker(traced, self.filename, self.waived,
                             self.findings)
        for stmt in (body_node.body if isinstance(body_node.body, list)
                     else [body_node.body]):
            checker.visit(stmt)

    def run(self) -> list[Finding]:
        jit_names, jit_attrs = _collect_jit_targets(self.tree)
        if jit_names or jit_attrs:
            _HotPathChecker(jit_names, jit_attrs, self.filename,
                            self.waived, self.findings).visit(self.tree)
        kernel_names = set()
        for n in ast.walk(self.tree):
            if isinstance(n, ast.Call) and (
                    _dotted(n.func) in ("pl.pallas_call", "pallas_call")):
                head = n.args[0] if n.args else None
                if isinstance(head, ast.Call) and _is_partial(head.func) \
                        and head.args:
                    head = head.args[0]
                if isinstance(head, ast.Name):
                    kernel_names.add(head.id)

        for n in ast.walk(self.tree):
            # decorated defs
            if isinstance(n, ast.FunctionDef):
                for dec in n.decorator_list:
                    spec = _jit_spec(dec)
                    if spec is not None:
                        self._check_donation(n.args, spec, n,
                                             f"def {n.name}")
                        self._lint_traced_fn(
                            n, self._traced_params(n.args, spec))
                        break
                if n.name in kernel_names:
                    spec = dict(static_names=set(), static_nums=set(),
                                donate_nums=set(), donate_names=set())
                    self._lint_traced_fn(n, self._traced_params(n.args, spec))
            # jax.jit(fn_or_lambda, ...) call sites
            if isinstance(n, ast.Call):
                spec = _jit_spec(n)
                if spec is None or not n.args:
                    continue
                target = n.args[0]
                if isinstance(target, ast.Lambda):
                    self._check_donation(target.args, spec, n, "jit(lambda)")
                    self._lint_traced_fn(
                        target, self._traced_params(target.args, spec))
                elif isinstance(target, ast.Name) and target.id in self.defs:
                    d = self.defs[target.id]
                    self._check_donation(d.args, spec, n,
                                         f"jit({target.id})")
                    self._lint_traced_fn(d, self._traced_params(d.args, spec))
        return self.findings


# -- public API -------------------------------------------------------------


def lint_source(src: str, filename: str = "<snippet>") -> list[Finding]:
    return _ModuleLinter(src, filename).run()


def lint_file(path: str | Path) -> list[Finding]:
    p = Path(path)
    try:
        return lint_source(p.read_text(), str(p))
    except SyntaxError as e:
        return [Finding("lint", "syntax-error", f"{p}:{e.lineno}", str(e))]


def run(roots: list[str | Path] | None = None) -> list[Finding]:
    """Lint the repo's traced code (``src/repro`` and ``scripts`` by
    default; tests deliberately excluded -- fixtures seed violations)."""
    if roots is None:
        base = Path(__file__).resolve().parents[3]
        roots = [base / "src" / "repro", base / "scripts"]
    findings: list[Finding] = []
    for root in roots:
        root = Path(root)
        files = sorted(root.rglob("*.py")) if root.is_dir() else [root]
        for f in files:
            findings += lint_file(f)
    return findings
