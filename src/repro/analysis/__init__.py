"""Static analysis & invariant checks (DESIGN.md #14).

Four passes, each returning ``list[Finding]`` from its ``run()``:

- ``jaxpr_audit``  -- format-flow auditor over the real executables
- ``pallas_check`` -- BlockSpec tile bounds / divisibility / ref dtypes
- ``retrace``      -- steady-state serving compiles nothing new
- ``lint``         -- AST rules over src/ and scripts/

``scripts/check.py`` drives all four; CI fails on any finding.
"""
from repro.analysis.common import Finding
from repro.analysis.retrace import RetraceError, RetraceGuard

__all__ = ["Finding", "RetraceError", "RetraceGuard",
           "jaxpr_audit", "pallas_check", "retrace", "lint"]
