"""jaxpr format-flow auditor.

Hyft's contract is that every intermediate lives in the format the next op
wants (DESIGN.md #14): conversions happen at the declared FP2FX / FX2FP /
quantize / mask boundaries and nowhere else.  This pass traces the *real*
executables (chunked prefill, decode burst, spec verify step, host serve
step, scanned decode loop, train step) to ClosedJaxprs and walks every eqn:

``format.f64``            any float64 value or convert target (an x64 leak
                          would silently double HBM traffic on every path).
``format.weak-promotion`` a ``convert_element_type`` whose input is a
                          *weak-typed* array of rank >= 1: a Python scalar
                          was broadcast against a tensor and the promotion
                          materialized in the hot path instead of folding.
``format.undeclared-convert``  a rank >= 1 dtype change whose (src, dst)
                          pair is not a declared format boundary.
``host.op-in-loop``       callbacks / ``device_put`` inside a scan or while
                          body -- a host round-trip per decode step.
``donation.cache-not-donated``  an executable that threads a KV cache whose
                          lowered HLO does not alias every cache leaf to an
                          output (each step then copies the whole cache).

Scalar (rank-0) weak converts are NOT findings: XLA constant-folds them.
They are tallied and reported by ``scripts/check.py --verbose`` as churn.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.analysis.common import Finding, eqn_location, walk_eqns

# Declared format boundaries (DESIGN.md #14): (src, dst) dtype-name pairs a
# rank >= 1 convert_element_type may legitimately cross.  Everything else in
# a traced executable is a finding.
DECLARED_BOUNDARIES: frozenset[tuple[str, str]] = frozenset({
    # FP2FX / FX2FP and float-field assembly (numerics.py)
    ("float32", "int32"), ("int32", "float32"),
    # fp2fx8 KV-cache quantize (store) and fused dequant (load)
    ("int32", "int8"), ("float32", "int8"),
    ("int8", "int32"), ("int8", "float32"),
    # masks / gates / validity lanes
    ("bool", "int32"), ("bool", "float32"),
    ("int32", "bool"), ("float32", "bool"),
    # parameter / activation precision (mixed-precision configs)
    ("float32", "bfloat16"), ("bfloat16", "float32"),
    ("float32", "float16"), ("float16", "float32"),
})

_HOST_PRIMS = frozenset({
    "io_callback", "pure_callback", "debug_callback", "callback",
    "device_put", "infeed", "outfeed",
})


@dataclasses.dataclass
class AuditTarget:
    """One executable to audit.

    ``make`` returns ``(fn, args)`` at smoke size; ``cache_argnum`` names the
    positional arg holding the KV cache/pool (``None`` = no cache threaded,
    donation not checked).  ``fn`` must be the *jitted* callable so the
    donation check can lower it.
    """
    name: str
    make: Callable[[], tuple[Callable, tuple]]
    cache_argnum: int | None = None


def audit_jaxpr(closed, name: str,
                stats: dict[str, int] | None = None) -> list[Finding]:
    """Walk one ClosedJaxpr applying the format-flow rules."""
    findings: list[Finding] = []
    for eqn, in_loop in walk_eqns(closed.jaxpr):
        prim = eqn.primitive.name
        if prim in _HOST_PRIMS and in_loop:
            findings.append(Finding(
                "jaxpr", "host.op-in-loop", eqn_location(eqn),
                f"{name}: `{prim}` inside a scan/while body -- host "
                f"round-trip per loop step"))
        for var in eqn.outvars:
            aval = var.aval
            if getattr(aval, "dtype", None) is not None \
                    and str(aval.dtype) == "float64":
                findings.append(Finding(
                    "jaxpr", "format.f64", eqn_location(eqn),
                    f"{name}: float64 value produced by `{prim}`"))
        if prim != "convert_element_type":
            continue
        src = eqn.invars[0].aval
        src_dt, dst_dt = str(src.dtype), str(eqn.params["new_dtype"])
        if dst_dt == "float64":
            findings.append(Finding(
                "jaxpr", "format.f64", eqn_location(eqn),
                f"{name}: convert {src_dt} -> float64"))
            continue
        weak = bool(getattr(src, "weak_type", False))
        if len(src.shape) == 0:
            if stats is not None and weak:
                stats["scalar_weak_converts"] = \
                    stats.get("scalar_weak_converts", 0) + 1
            continue
        if weak:
            findings.append(Finding(
                "jaxpr", "format.weak-promotion", eqn_location(eqn),
                f"{name}: weak-typed {src_dt}{list(src.shape)} converted to "
                f"{dst_dt} -- a Python scalar was broadcast against a "
                f"tensor before the cast"))
        elif src_dt != dst_dt and (src_dt, dst_dt) not in DECLARED_BOUNDARIES:
            findings.append(Finding(
                "jaxpr", "format.undeclared-convert", eqn_location(eqn),
                f"{name}: {src_dt} -> {dst_dt} on shape {list(src.shape)} is "
                f"not a declared format boundary (DESIGN.md #14)"))
    return findings


# -- donation ---------------------------------------------------------------

_ARG_RE = re.compile(r"%arg(\d+):")


def _aliased_arg_indices(hlo_text: str) -> set[int]:
    """Flat arg indices of ``@main`` carrying ``tf.aliasing_output`` (the
    StableHLO marker for a donated buffer that the compiler accepted)."""
    m = re.search(r"func\.func public @main\(", hlo_text)
    if m is None:
        return set()
    end = hlo_text.find(") -> ", m.end())
    sig = hlo_text[m.end():end if end != -1 else m.end()]
    out: set[int] = set()
    spans = list(_ARG_RE.finditer(sig))
    for i, am in enumerate(spans):
        end = spans[i + 1].start() if i + 1 < len(spans) else len(sig)
        if "tf.aliasing_output" in sig[am.end():end]:
            out.add(int(am.group(1)))
    return out


def audit_donation(fn, args: tuple, cache_argnum: int, name: str) -> list[Finding]:
    """Check every leaf of ``args[cache_argnum]`` is donated (aliased to an
    output) in the lowered HLO of the jitted ``fn``."""
    try:
        text = fn.lower(*args).as_text()
    except Exception as e:  # not a jit-wrapped callable, or lowering failed
        return [Finding("jaxpr", "donation.unlowerable", name,
                        f"could not lower for the donation check: {e!r}")]
    aliased = _aliased_arg_indices(text)
    offset = sum(len(jax.tree_util.tree_leaves(a))
                 for a in args[:cache_argnum])
    keys = [jax.tree_util.keystr(kp) for kp, _ in
            jax.tree_util.tree_flatten_with_path(args[cache_argnum])[0]]
    findings = []
    for i, key in enumerate(keys):
        if offset + i not in aliased:
            findings.append(Finding(
                "jaxpr", "donation.cache-not-donated", name,
                f"cache leaf {key or '<root>'} (flat arg {offset + i}) is "
                f"not aliased to an output -- every call copies it"))
    return findings


# -- the real-executable registry -------------------------------------------


def default_targets() -> list[AuditTarget]:
    """The serving/training executables, built at smoke size (the shapes CI
    can afford; the rules are shape-independent)."""
    from repro.configs import get_config, smoke_config
    from repro.configs.base import ServeConfig, TrainConfig
    from repro.models import build_model, resolve_attn_mode
    from repro.models.layers import unbox
    from repro.serve import engine, scheduler, spec
    from repro.train.step import make_step_fn
    from repro import optim

    I32 = jnp.int32
    cfg = smoke_config(get_config("qwen2-1.5b")).with_(
        softmax_impl="hyft16", vocab=64)
    model = build_model(cfg)
    params = unbox(model.init(jax.random.PRNGKey(0)))
    n, L, W, K = 3, 32, 8, 3

    def serve_parts(cache_dtype):
        scfg = ServeConfig(max_len=L, cache_dtype=cache_dtype, n_slots=n,
                           decode_burst=4, attn_mode="kernel", draft_k=K)
        m = resolve_attn_mode(model, scfg.attn_mode)
        bkey = scheduler._burst_key_cfg(scfg)
        cache = m.init_cache(params, n, L, cache_dtype)
        return scfg, bkey, m, cache

    def mk_prefill_chunk(cache_dtype):
        def make():
            scfg, bkey, m, cache = serve_parts(cache_dtype)
            fn = engine.build_prefill_chunk(m, bkey, W)
            args = (params, cache, jnp.zeros((n, W), I32), jnp.zeros(n, I32),
                    jnp.ones(n, I32), jnp.zeros(n, bool))
            return fn, args
        return make

    def mk_burst(cache_dtype):
        def make():
            scfg, bkey, m, cache = serve_parts(cache_dtype)
            fn = scheduler.build_burst(m, bkey, scfg.decode_burst)
            args = (params, cache, jnp.zeros((n, 1), I32), jnp.ones(n, I32),
                    jnp.zeros(n, bool), jnp.ones(n, I32),
                    jnp.full(n, scheduler.TTL_NONE, I32),
                    jax.random.PRNGKey(0))
            return fn, args
        return make

    def mk_spec_step(cache_dtype):
        def make():
            scfg, bkey, m, cache = serve_parts(cache_dtype)
            fn = spec.build_spec_step(m, bkey, K)
            args = (params, cache, jnp.zeros((n, 1), I32),
                    jnp.zeros((n, K), I32), jnp.zeros(n, I32),
                    jnp.ones(n, I32), jnp.zeros(n, bool), jnp.ones(n, I32))
            return fn, args
        return make

    def mk_serve_step():
        scfg, bkey, m, cache = serve_parts("float32")
        fn = engine.build_serve_step(m, scfg)
        return fn, (params, cache, jnp.zeros((n, 1), I32), 4,
                    jax.random.PRNGKey(0))

    def mk_decode_loop():
        scfg, bkey, m, cache = serve_parts("float32")
        fn = engine.build_decode_loop(m, scfg, 4)
        return fn, (params, cache, jnp.zeros((n, 1), I32), 4,
                    jax.random.PRNGKey(0))

    def mk_train_step():
        step = jax.jit(make_step_fn(model, TrainConfig(), optim.OptConfig()),
                       donate_argnums=(0,))
        state = {"params": params,
                 "opt": optim.init(optim.OptConfig(), params),
                 "step": jnp.zeros((), I32), "rng": jax.random.PRNGKey(0)}
        batch = {"tokens": jnp.zeros((2, 16), I32),
                 "targets": jnp.zeros((2, 16), I32)}
        return step, (state, batch)

    targets = []
    for cd in ("float32", "fp2fx8"):
        targets.append(AuditTarget(f"prefill_chunk[{cd}]",
                                   mk_prefill_chunk(cd), cache_argnum=1))
        targets.append(AuditTarget(f"decode_burst[{cd}]", mk_burst(cd),
                                   cache_argnum=1))
        targets.append(AuditTarget(f"spec_step[{cd}]", mk_spec_step(cd),
                                   cache_argnum=1))
    targets.append(AuditTarget("serve_step[float32]", mk_serve_step,
                               cache_argnum=1))
    targets.append(AuditTarget("decode_loop[float32]", mk_decode_loop,
                               cache_argnum=1))
    targets.append(AuditTarget("train_step", mk_train_step, cache_argnum=None))
    return targets


def run(targets: list[AuditTarget] | None = None,
        stats: dict[str, int] | None = None) -> list[Finding]:
    """Audit every target; returns all findings (empty = clean)."""
    findings: list[Finding] = []
    for t in targets if targets is not None else default_targets():
        fn, args = t.make()
        closed = jax.make_jaxpr(fn)(*args)
        findings += audit_jaxpr(closed, t.name, stats=stats)
        if t.cache_argnum is not None:
            findings += audit_donation(fn, args, t.cache_argnum, t.name)
    return findings
