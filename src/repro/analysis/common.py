"""Shared plumbing for the static-analysis passes.

A ``Finding`` is one violated invariant, printable as
``[pass.rule] where -- detail``.  The jaxpr helpers here are the only place
that touches JAX internals for eqn walking, so an upstream API move breaks
one module, not four.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

from jax._src import core as jcore
from jax._src import source_info_util


@dataclasses.dataclass(frozen=True)
class Finding:
    """One violated invariant surfaced by an analysis pass."""
    pass_name: str   # "jaxpr" | "pallas" | "retrace" | "lint"
    rule: str        # e.g. "format.weak-promotion"
    where: str       # "file:line" or the executable/kernel name
    detail: str

    def __str__(self) -> str:
        return f"[{self.pass_name}.{self.rule}] {self.where} -- {self.detail}"


def subjaxprs(eqn: jcore.JaxprEqn) -> list[jcore.Jaxpr]:
    """Sub-jaxprs carried in an eqn's params (scan/while/cond/jit bodies,
    custom-vjp branches, Pallas index maps are NOT included -- those live in
    grid_mapping and are handled by the tile checker)."""
    out: list[jcore.Jaxpr] = []
    for v in eqn.params.values():
        items = v if isinstance(v, (list, tuple)) else [v]
        for x in items:
            if isinstance(x, jcore.ClosedJaxpr):
                out.append(x.jaxpr)
            elif isinstance(x, jcore.Jaxpr):
                out.append(x)
    return out


_LOOP_PRIMS = frozenset({"scan", "while"})


def walk_eqns(jaxpr: jcore.Jaxpr,
              in_loop: bool = False) -> Iterator[tuple[jcore.JaxprEqn, bool]]:
    """Yield every eqn in the jaxpr tree with a flag marking whether it sits
    inside a ``lax.scan`` / ``lax.while_loop`` body."""
    for eqn in jaxpr.eqns:
        yield eqn, in_loop
        inner = in_loop or eqn.primitive.name in _LOOP_PRIMS
        for sub in subjaxprs(eqn):
            yield from walk_eqns(sub, inner)


def eqn_location(eqn: jcore.JaxprEqn) -> str:
    """Best-effort ``file:line`` for an eqn, preferring repo frames over the
    caller's trace harness."""
    frames = list(source_info_util.user_frames(eqn.source_info))
    for fr in frames:
        if "/src/repro/" in fr.file_name.replace("\\", "/"):
            return f"{fr.file_name}:{fr.start_line}"
    if frames:
        return f"{frames[0].file_name}:{frames[0].start_line}"
    return "<unknown>"
