"""Sharded, atomic, restart-safe checkpointing (numpy .npz + JSON manifest).

Orbax is not available offline; this implements the same guarantees:
  * atomic publish — write to ``step_<n>.tmp/`` then ``os.rename`` (POSIX
    atomic within a filesystem), so a crash never leaves a half checkpoint;
  * a JSON manifest carrying the pytree structure, dtypes, and step;
  * keep-k garbage collection;
  * ``latest_step()`` / ``restore()`` used by the fault-tolerance restart
    manager (a restarted or *resized* job reloads and re-shards — arrays are
    saved unsharded per-leaf here; a real multi-host deployment would write
    per-host shard files with the same manifest, noted in DESIGN.md).
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np

MANIFEST = "manifest.json"


def _leaf_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(kp), v) for kp, v in flat]


def save(ckpt_dir: str, step: int, state, keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves = _leaf_paths(state)
    names, dtypes, arrays = [], [], {}
    for i, (kp, v) in enumerate(leaves):
        arr = np.asarray(jax.device_get(v))
        dtypes.append(str(arr.dtype))
        if arr.dtype.name not in np.sctypeDict:  # ml_dtypes (bf16, fp8, ...)
            arr = arr.view(np.uint8 if arr.dtype.itemsize == 1 else
                           np.uint16 if arr.dtype.itemsize == 2 else np.uint32)
        arrays[f"a{i}"] = arr
        names.append(kp)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump({"step": step, "leaf_paths": names, "dtypes": dtypes}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, d, MANIFEST)):
                out.append(int(d[5:]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, like, shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    NamedShardings — arrays are placed (re-sharded) on restore, which is how
    an elastically-resized mesh reloads old checkpoints."""
    import ml_dtypes  # ships with jax

    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, MANIFEST)) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    by_path = {}
    for i, kp in enumerate(manifest["leaf_paths"]):
        arr = data[f"a{i}"]
        want = manifest.get("dtypes", [None] * (i + 1))[i]
        if want and want != str(arr.dtype):  # stored as uint bits
            arr = arr.view(np.dtype(getattr(ml_dtypes, want, want)))
        by_path[kp] = arr

    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_flat = (jax.tree.leaves(shardings) if shardings is not None
                  else [None] * len(flat))
    out = []
    for (kp, leaf), sh in zip(flat, shard_flat):
        arr = by_path[jax.tree_util.keystr(kp)]
        if sh is not None:
            out.append(jax.device_put(arr.astype(leaf.dtype), sh))
        else:
            out.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["step"]
