"""Paper Table 3 (hardware cost) — fabric-free reproduction.

Two complementary views (DESIGN.md §2):
  1. The op-count cost model (core.costmodel): area/latency/FOM per softmax
     design at N=8, W=16/32 — reproduces the paper's ordering and the
     ~15x resource / large latency gains vs the all-FP32 engine.
  2. Measured wall-time of the jitted emulations on attention-shaped rows
     (bench_softmax) — the software-visible latency ranking.
"""
from __future__ import annotations

from repro.core.costmodel import table3


def run(report):
    for r in table3(N=8):
        report(
            f"table3,{r['name']},area={r['area']:.0f},latency={r['latency']:.1f},"
            f"period={r['period']:.1f},fom={r['fom'] * 1000:.2f},"
            f"area_x_fp32={r['area_ratio_vs_fp32']:.1f},"
            f"latency_x_fp32={r['latency_ratio_vs_fp32']:.1f}")
