"""Render roofline tables: the cached dry-run cells (analytic model over
``results/dryrun``) and, via ``live``, the measured achieved-vs-peak rows
the §16 cost book wrote into the BENCH_*.json artifacts."""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load_cells(mesh="single", tag="baseline"):
    cells = []
    for f in sorted(glob.glob(os.path.join(RESULTS, f"{mesh}__*__{tag}.json"))):
        cells.append(json.load(open(f)))
    return cells


def run(report, mesh="single", tag="baseline"):
    cells = load_cells(mesh, tag)
    if not cells:
        report(f"roofline,{mesh},{tag},NO_CELLS (run repro.launch.dryrun first)")
        return
    for r in cells:
        if r["status"] == "skipped":
            report(f"roofline,{mesh},{r['arch']},{r['shape']},SKIP")
            continue
        if r["status"] != "ok":
            report(f"roofline,{mesh},{r['arch']},{r['shape']},ERROR")
            continue
        roof = r["roofline"]
        report(
            f"roofline,{mesh},{r['arch']},{r['shape']},"
            f"compute_s={roof['compute_s']:.4e},"
            f"memory_s={roof['memory_s']:.4e},"
            f"collective_s={roof['collective_s']:.4e},"
            f"dominant={roof['dominant']},"
            f"frac={roof['roofline_fraction']:.3f},"
            f"useful={r.get('useful_flops_ratio', 0):.3f},"
            f"peak_gib={r['memory']['peak_device_bytes'] / 2 ** 30:.2f}")


def _live_rows(results: dict):
    """(tag, executable, join) triples from one BENCH artifact's measured
    cost-book summaries, wherever they appear."""
    for r in results.get("kernels", []):
        if "roofline_fraction" in r:
            yield "kernels", r["kernel"], r
    for r in results.get("e2e", []):
        for exe, j in r.get("roofline", {}).items():
            if "roofline_fraction" in j:
                yield f"e2e.{r['loop']}.{r['cache']}", exe, j
    for section in ("engines", "prefix_engines", "spec_engines",
                    "chunked_engines"):
        for name, er in results.get(section, {}).items():
            for exe, j in er.get("roofline", {}).items():
                if "roofline_fraction" in j:
                    yield f"{section}.{name}", exe, j


def live(report, root: str = ".") -> None:
    """Measured achieved-vs-peak table from the BENCH_*.json artifacts —
    the cost_analysis() x wall-time joins the benches recorded, as opposed
    to the analytic dry-run cells above."""
    found = False
    for fname in ("BENCH_kernels.json", "BENCH_decode.json",
                  "BENCH_serve.json"):
        path = os.path.join(root, fname)
        if not os.path.exists(path):
            continue
        with open(path) as f:
            results = json.load(f)
        prov = results.get("provenance", {})
        for tag, exe, j in _live_rows(results):
            found = True
            report(
                f"roofline_live,{fname},{tag},{exe},"
                f"gflops={j['achieved_gflops']:.3f},"
                f"gbps={j['achieved_gbps']:.3f},"
                f"frac={j['roofline_fraction']:.2e},"
                f"bound={j['bound_dominant']},"
                f"backend={prov.get('backend', '?')},"
                f"interpret={prov.get('interpret', '?')}")
    if not found:
        report("roofline_live,NO_ROWS (run the benchmarks first)")
