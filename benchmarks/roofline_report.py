"""Render the roofline table from cached dry-run JSONs (results/dryrun)."""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load_cells(mesh="single", tag="baseline"):
    cells = []
    for f in sorted(glob.glob(os.path.join(RESULTS, f"{mesh}__*__{tag}.json"))):
        cells.append(json.load(open(f)))
    return cells


def run(report, mesh="single", tag="baseline"):
    cells = load_cells(mesh, tag)
    if not cells:
        report(f"roofline,{mesh},{tag},NO_CELLS (run repro.launch.dryrun first)")
        return
    for r in cells:
        if r["status"] == "skipped":
            report(f"roofline,{mesh},{r['arch']},{r['shape']},SKIP")
            continue
        if r["status"] != "ok":
            report(f"roofline,{mesh},{r['arch']},{r['shape']},ERROR")
            continue
        roof = r["roofline"]
        report(
            f"roofline,{mesh},{r['arch']},{r['shape']},"
            f"compute_s={roof['compute_s']:.4e},"
            f"memory_s={roof['memory_s']:.4e},"
            f"collective_s={roof['collective_s']:.4e},"
            f"dominant={roof['dominant']},"
            f"frac={roof['roofline_fraction']:.3f},"
            f"useful={r.get('useful_flops_ratio', 0):.3f},"
            f"peak_gib={r['memory']['peak_device_bytes'] / 2 ** 30:.2f}")
