# One function per paper table. Print ``name,us_per_call,derived`` CSV.
import time


def main() -> None:
    from benchmarks import (bench_decode, bench_kernels, bench_serve,
                            bench_softmax, roofline_report, table1_accuracy,
                            table2_training, table3_hardware)
    from repro.obs import ledger

    def report(line: str) -> None:
        print(line, flush=True)

    t0 = time.time()
    report("# Hyft benchmark harness — one section per paper table")
    report("## Table 3: hardware cost model (fabric-free op counts)")
    table3_hardware.run(report)
    report("## Softmax emulation wall-time (CPU, jitted)")
    softmax_results = bench_softmax.run(report)
    ledger.finalize("BENCH_softmax.json", "softmax", softmax_results)
    report("# wrote BENCH_softmax.json")
    report("## Kernel microbench: us/call + achieved-vs-peak per registry "
           "kernel")
    kernel_results = bench_kernels.run(report)
    ledger.finalize("BENCH_kernels.json", "kernels", kernel_results)
    report("# wrote BENCH_kernels.json")
    report("## Decode: op latency (incl. split-K / fp2fx8) + e2e throughput")
    decode_results = bench_decode.run(report)
    ledger.finalize("BENCH_decode.json", "decode", decode_results)
    report("# wrote BENCH_decode.json")
    report("## Serving: continuous vs lockstep + paged/prefix-cache vs dense")
    serve_results = bench_serve.run(report)
    ledger.finalize("BENCH_serve.json", "serve", serve_results)
    report("# wrote BENCH_serve.json")
    report("## Table 1: drop-in inference accuracy (synthetic-GLUE proxy)")
    table1_accuracy.run(report)
    report("## Table 2: training-through-Hyft accuracy (proxy)")
    table2_training.run(report)
    report("## Roofline (from cached dry-run artifacts)")
    roofline_report.run(report)
    report("## Roofline (live, from the BENCH artifacts just written)")
    roofline_report.live(report)
    report(f"# done in {time.time() - t0:.1f}s")


if __name__ == '__main__':
    main()
