"""Wall-time of each softmax implementation (jitted, CPU) + the Pallas
kernels in interpret mode, on attention-shaped batches.

Absolute numbers are CPU-emulation times (the TPU targets are the roofline
figures); the *relative* ordering of the emulations tracks operation count.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.registry import available, get_softmax

SHAPES = [(1024, 128), (256, 1024)]


def _time(fn, *args, iters=20):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run(report):
    """Returns the machine-readable results dict (also printed as CSV)."""
    key = jax.random.PRNGKey(0)
    out = []
    for rows, cols in SHAPES:
        z = jax.random.normal(key, (rows, cols), jnp.float32) * 3
        base = None
        for impl in ["exact", "hyft32", "hyft16", "base2", "koca", "lut8",
                     "softermax"]:
            fn = jax.jit(get_softmax(impl))
            us = _time(fn, z)
            base = base or us
            out.append({"impl": impl, "shape": f"{rows}x{cols}",
                        "us_per_call": us, "vs_exact": us / base})
            report(f"bench_softmax,{impl},shape={rows}x{cols},"
                   f"us_per_call={us:.1f},vs_exact={us / base:.2f}")
    return {"softmax": out}


if __name__ == "__main__":
    import argparse

    from repro.obs import ledger

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_softmax.json")
    args = ap.parse_args()
    res = run(print)
    ledger.finalize(args.json, "softmax", res)
    print(f"# wrote {args.json}")
