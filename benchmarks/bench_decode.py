"""Decode benchmarks: attention-op latency + end-to-end decode throughput.

Two sections, both emitted as text lines via ``report`` AND returned as a
dict (``benchmarks/run.py`` and the ``__main__`` entry persist it to
``BENCH_decode.json``):

  op  — masked Sq=1 decode attention across the modes: unfused, chunked,
        monolithic fused kernel, split-K decode kernel, and split-K over the
        fp2fx8-quantized (int8 + per-head scale) KV cache.  The Sk=2048
        masked shape is the acceptance case the split-K kernel must handle
        without falling back.
  e2e — ``serve.engine.generate`` tokens/sec on a tiny model: the per-token
        host dispatch loop vs the on-device ``lax.scan`` loop, dense vs
        fp2fx8 cache.  This measures exactly what the scanned loop exists
        for: killing the per-token Python round-trip.
  numerics — hybrid-format telemetry (``ServeConfig.telemetry``, DESIGN.md
        §15) from a tiny slot-pool serve, fp32 vs fp2fx8 cache: the
        realized softmax-input exponent range pre/post max-subtraction (the
        quantity the paper's hybrid-format argument rests on), the fp2fx8
        KV scale histogram + int8 saturation rate, and the §14
        format-boundary convert volume.

Absolute numbers are CPU times (Pallas in interpreter mode; on TPU it is the
compiled path) — read the relative trends.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.hyft import HYFT32
from repro.kernels import ops
from repro.models.attention import (chunked_hyft_attention, fp2fx8_quantize,
                                    unfused_attention)

F32 = jnp.float32
OP_SHAPES = [  # (B, Hq, Hkv, Sk, D, valid_len)
    (4, 8, 4, 512, 64, 300),
    (1, 16, 8, 2048, 64, 1500),
]


def _time(fn, *args, iters=10):
    jax.block_until_ready(fn(*args))  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def _op_section(report, shapes, iters):
    rows = []
    key = jax.random.PRNGKey(0)
    for B, Hq, Hkv, Sk, D, valid in shapes:
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, Hq, 1, D), F32)
        k = jax.random.normal(ks[1], (B, Hkv, Sk, D), F32)
        v = jax.random.normal(ks[2], (B, Hkv, Sk, D), F32)
        mask = (jnp.arange(Sk)[None, :] < valid).astype(F32).repeat(B, 0)
        kr, ksc = fp2fx8_quantize(k)
        vr, vsc = fp2fx8_quantize(v)

        modes = {
            "unfused": jax.jit(lambda q, k, v, m: unfused_attention(
                q, k, v, "hyft32", causal=False, kv_len_mask=m > 0)),
            "kernel": jax.jit(lambda q, k, v, m: ops.hyft_attention(
                q, k, v, HYFT32, causal=False, kv_len_mask=m)),
            "chunked": jax.jit(lambda q, k, v, m: chunked_hyft_attention(
                q, k, v, HYFT32, False, min(512, Sk), 0, m)),
            "splitk": jax.jit(lambda q, k, v, m: ops.hyft_decode_attention(
                q, k, v, HYFT32, kv_len_mask=m)),
            "splitk_fp2fx8": jax.jit(
                lambda q, kr, vr, m, ksc=ksc, vsc=vsc:
                ops.hyft_decode_attention(q, kr, vr, HYFT32, kv_len_mask=m,
                                          k_scale=ksc, v_scale=vsc)),
        }
        shape = f"B{B}xH{Hq}xS{Sk}(valid={valid})xD{D}"
        base = None
        for name, fn in modes.items():
            args = (q, kr, vr, mask) if name == "splitk_fp2fx8" else (q, k, v, mask)
            us = _time(fn, *args, iters=iters)
            base = us if name == "unfused" else base
            rows.append({"mode": name, "shape": shape, "us_per_step": us,
                         "vs_unfused": us / base})
            report(f"bench_decode,{name},shape={shape},us_per_step={us:.1f},"
                   f"vs_unfused={us / base:.2f}")
    return rows


def _e2e_section(report, max_new, batch):
    from repro.configs import get_config, smoke_config
    from repro.configs.base import ServeConfig
    from repro.models import build_model
    from repro.models.layers import unbox
    from repro.obs.profile import CostBook
    from repro.serve.engine import generate

    cfg = smoke_config(get_config("olmo-1b")).with_(
        softmax_impl="hyft16", vocab=128, n_layers=2)
    model = build_model(cfg)
    params = unbox(model.init(jax.random.PRNGKey(0)))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (batch, 8), 0,
                                cfg.vocab, jnp.int32)
    b = {"tokens": tokens}

    rows = []
    for loop, cache_dtype in (("host", "float32"), ("scan", "float32"),
                              ("scan", "fp2fx8")):
        scfg = ServeConfig(max_len=8 + max_new + 1, cache_dtype=cache_dtype,
                           decode_loop=loop)
        out = generate(model, params, b, scfg, max_new=max_new)  # compile
        jax.block_until_ready(out)
        # timed pass carries a cost book: real cost_analysis() FLOPs/bytes
        # per executable, joined against the walls generate measures
        book = CostBook(enabled=True)
        t0 = time.perf_counter()
        out = generate(model, params, b, scfg, max_new=max_new, profile=book)
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        tps = batch * max_new / dt
        rows.append({"loop": loop, "cache": cache_dtype,
                     "tokens_per_s": tps,
                     "us_per_token": dt / (batch * max_new) * 1e6,
                     "roofline": book.summary()})
        report(f"bench_decode_e2e,loop={loop},cache={cache_dtype},"
               f"tokens_per_s={tps:.1f},us_per_token={dt / (batch * max_new) * 1e6:.1f}")
        for name, r in book.summary().items():
            if "roofline_fraction" in r:
                report(f"bench_decode_roofline,loop={loop},"
                       f"cache={cache_dtype},exe={name},"
                       f"gflops={r['achieved_gflops']:.3f},"
                       f"gbps={r['achieved_gbps']:.3f},"
                       f"frac={r['roofline_fraction']:.2e},"
                       f"bound={r['bound_dominant']}")
    return rows


def _numerics_section(report, batch, max_new):
    """Serve a tiny workload with ``telemetry=True`` and report the
    per-burst device-side numeric stats the hybrid-format design rests on.
    The fp32 and fp2fx8 engines see the same prompts, so the z-range rows
    are directly comparable and the fp2fx8 row adds the KV-quantization
    telemetry (scale spread, saturation, convert volume)."""
    import numpy as np

    from repro.configs import get_config, smoke_config
    from repro.configs.base import ServeConfig
    from repro.models import build_model
    from repro.models.layers import unbox
    from repro.serve.scheduler import Request, SlotPoolEngine

    cfg = smoke_config(get_config("olmo-1b")).with_(
        softmax_impl="hyft16", vocab=128, n_layers=2)
    model = build_model(cfg)
    params = unbox(model.init(jax.random.PRNGKey(0)))
    rng = np.random.default_rng(9)
    reqs = [Request(rid=i,
                    tokens=rng.integers(0, cfg.vocab,
                                        int(rng.integers(4, 12))).astype(
                                            np.int32),
                    max_new=max_new, arrival=0.0) for i in range(batch)]
    rows = {}
    for cache_dtype in ("float32", "fp2fx8"):
        scfg = ServeConfig(max_len=12 + max_new + 1, cache_dtype=cache_dtype,
                           scheduler="continuous", n_slots=batch,
                           decode_burst=4, telemetry=True)
        eng = SlotPoolEngine(model, params, scfg)
        eng.prewarm(max(len(r.tokens) for r in reqs))
        eng.run(reqs)
        s = eng.obs.numerics.summary()
        rows[cache_dtype] = s
        extra = (f",kv_saturation_rate={s.get('kv_saturation_rate', 0):.4f},"
                 f"kv_scale_bins={len(s.get('kv_scale_hist', {}))}"
                 if cache_dtype == "fp2fx8" else "")
        report(f"bench_decode_numerics,cache={cache_dtype},"
               f"z_max={s['z_max']:.2f},z_min={s['z_min']:.2f},"
               f"zsub_min={s['zsub_min']:.2f},"
               f"converts={s.get('converts', 0)}{extra}")
    return rows


def run(report, quick: bool = False):
    """Run all sections; returns the machine-readable results dict."""
    shapes = OP_SHAPES[1:] if quick else OP_SHAPES  # keep the Sk=2048 case
    results = {
        "op": _op_section(report, shapes, iters=3 if quick else 10),
        "e2e": _e2e_section(report, max_new=16 if quick else 32,
                            batch=2 if quick else 4),
        "numerics": _numerics_section(report, batch=2 if quick else 4,
                                      max_new=8 if quick else 16),
    }
    return results


if __name__ == "__main__":
    import argparse

    from repro.obs import ledger, profile

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_decode.json")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: fewer iters, Sk=2048 op shape only")
    ap.add_argument("--xla-profile", default=None, metavar="DIR",
                    help="jax.profiler capture window around the bench "
                         "(xplane + trace.json.gz under DIR)")
    ap.add_argument("--ledger", default="auto",
                    help="ledger path ('auto' = next to --json, 'none' to "
                         "skip the append)")
    args = ap.parse_args()
    with profile.xla_profile(args.xla_profile):
        res = run(print, quick=args.quick)
    ledger.finalize(args.json, "decode", res,
                    mode="smoke" if args.quick else "full",
                    ledger_path=None if args.ledger == "none"
                    else args.ledger)
    print(f"# wrote {args.json}")
