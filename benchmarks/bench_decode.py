"""Masked decode-attention latency: fused kernel vs unfused vs chunked.

The serving scenario the fused path exists for: one query row per sequence
(Sq=1) against a padded KV cache with a per-batch validity mask.  All three
modes honor the shared mask contract (repro.kernels.ops), so this is an
apples-to-apples latency comparison of the same masked computation.

Absolute numbers are CPU times (the Pallas kernel runs in interpreter mode
here; on TPU it is the compiled path), so read the *relative* trend and the
fact that the fused path no longer falls back to unfused when a mask is
present — the regression this benchmark guards.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.hyft import HYFT32
from repro.kernels import ops
from repro.models.attention import chunked_hyft_attention, unfused_attention

F32 = jnp.float32
SHAPES = [  # (B, Hq, Hkv, Sk, D, valid_len)
    (4, 8, 4, 512, 64, 300),
    (1, 16, 8, 2048, 64, 1500),
]


def _time(fn, *args, iters=10):
    jax.block_until_ready(fn(*args))  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run(report):
    key = jax.random.PRNGKey(0)
    for B, Hq, Hkv, Sk, D, valid in SHAPES:
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, Hq, 1, D), F32)
        k = jax.random.normal(ks[1], (B, Hkv, Sk, D), F32)
        v = jax.random.normal(ks[2], (B, Hkv, Sk, D), F32)
        mask = (jnp.arange(Sk)[None, :] < valid).astype(F32).repeat(B, 0)

        unfused = jax.jit(lambda q, k, v, m: unfused_attention(
            q, k, v, "hyft32", causal=False, kv_len_mask=m > 0))
        fused = jax.jit(lambda q, k, v, m: ops.hyft_attention(
            q, k, v, HYFT32, causal=False, kv_len_mask=m))
        chunked = jax.jit(lambda q, k, v, m: chunked_hyft_attention(
            q, k, v, HYFT32, False, min(512, Sk), 0, m))

        shape = f"B{B}xH{Hq}xS{Sk}(valid={valid})xD{D}"
        us_u = _time(unfused, q, k, v, mask)
        us_f = _time(fused, q, k, v, mask)
        us_c = _time(chunked, q, k, v, mask)
        report(f"bench_decode,unfused,shape={shape},us_per_step={us_u:.1f}")
        report(f"bench_decode,kernel,shape={shape},us_per_step={us_f:.1f},"
               f"vs_unfused={us_f / us_u:.2f}")
        report(f"bench_decode,chunked,shape={shape},us_per_step={us_c:.1f},"
               f"vs_unfused={us_c / us_u:.2f}")
