"""Paper Table 2 (training accuracy) proxy.

The paper fine-tunes BERT *through* Hyft (forward + the accelerator's own
backward) and shows accuracy parity.  Proxy: train the tiny classifier from
scratch with each softmax in the loop (hyft grad mode) and compare final
accuracy/loss against exact-softmax training.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import optim
from repro.data.synthetic import classify_batch

F32 = jnp.float32
IMPLS = ["exact", "hyft32", "hyft16", "base2"]


def _train_with(softmax, steps=120, seed=0, loss_scale=1.0):
    from benchmarks.table1_accuracy import (_bert_proxy_cfg, _classifier_init,
                                            _logits)
    cfg = _bert_proxy_cfg(softmax)
    params = _classifier_init(jax.random.PRNGKey(seed), cfg)
    ocfg = optim.OptConfig(name="adamw", lr=2e-3, weight_decay=0.0)
    ost = optim.init(ocfg, params)

    @jax.jit
    def step(params, ost, tokens, labels):
        def loss_fn(p):
            lg = _logits(p, tokens, cfg)
            return loss_scale * jnp.mean(
                -jax.nn.log_softmax(lg)[jnp.arange(lg.shape[0]), labels])
        loss, g = jax.value_and_grad(loss_fn)(params)
        g = jax.tree.map(lambda x: x / loss_scale, g)
        params, ost = optim.update(ocfg, g, ost, params)
        return params, ost, loss / loss_scale

    loss = jnp.inf
    for s in range(steps):
        b = classify_batch(seed, s, 64, 24, vocab=cfg.vocab)
        params, ost, loss = step(params, ost, b["tokens"], b["labels"])

    # eval accuracy with the SAME softmax it was trained with
    correct = total = 0
    for s in range(8):
        b = classify_batch(seed, 2000 + s, 64, 24, vocab=cfg.vocab)
        lg = _logits(params, b["tokens"], cfg)
        correct += int(jnp.sum(jnp.argmax(lg, -1) == b["labels"]))
        total += lg.shape[0]
    return correct / total, float(loss)


def run(report):
    """Key reproduction finding: the accelerator's fixed-point backward adder
    tree (bwd_acc_bits fractional bits) underflows small gradients; with
    standard AMP-style loss scaling (the universal practice for fp16
    training, which Hyft16's FP16 I/O implies) training parity holds."""
    base_acc = None
    for impl in IMPLS:
        for scale in (1.0, 256.0):
            acc, loss = _train_with(impl, loss_scale=scale)
            if base_acc is None:
                base_acc = acc
            report(f"table2,{impl},loss_scale={scale:.0f},"
                   f"train_acc={acc:.4f},delta={acc - base_acc:+.4f},"
                   f"final_loss={loss:.4f}")
