"""Paper Table 1 (inference accuracy) proxy.

The paper drops Hyft into a fine-tuned BERT and reports GLUE/SQuAD accuracy
unchanged vs the original softmax, while [13]/[29] degrade.  Offline proxy
(no GLUE/torch in the container): train a small BERT-style classifier on the
synthetic marker-classification task with EXACT softmax, then swap the
softmax at inference time and measure accuracy deltas — the same drop-in
protocol as the paper.

Also reports distribution-level softmax error metrics (mean/max abs, KL) on
attention-logit-shaped inputs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import optim
from repro.configs import get_config, smoke_config
from repro.data.synthetic import classify_batch
from repro.models import transformer
from repro.models.layers import param, unbox
from repro.core.registry import get_softmax

F32 = jnp.float32
IMPLS = ["exact", "hyft32", "hyft16", "koca", "base2", "lut8"]


def _bert_proxy_cfg(softmax="exact"):
    return smoke_config(get_config("bert-base")).with_(
        softmax_impl=softmax, vocab=64, n_layers=2, compute_dtype="float32")


def _classifier_init(key, cfg, n_classes=4):
    p = {"backbone": transformer.init(key, cfg),
         "head": {"w": param(jax.random.fold_in(key, 1),
                             (cfg.d_model, n_classes), (None, None), F32)}}
    return unbox(p)


def _logits(params, tokens, cfg):
    hid, _ = transformer.forward(params["backbone"], tokens, cfg,
                                 remat="none", causal=False)
    pooled = jnp.mean(hid.astype(F32), axis=1)
    return pooled @ params["head"]["w"]


def _train_classifier(steps=150, seed=0):
    cfg = _bert_proxy_cfg("exact")
    params = _classifier_init(jax.random.PRNGKey(seed), cfg)
    ocfg = optim.OptConfig(name="adamw", lr=2e-3, weight_decay=0.0)
    ost = optim.init(ocfg, params)

    @jax.jit
    def step(params, ost, tokens, labels):
        def loss_fn(p):
            lg = _logits(p, tokens, cfg)
            return jnp.mean(
                -jax.nn.log_softmax(lg)[jnp.arange(lg.shape[0]), labels])
        loss, g = jax.value_and_grad(loss_fn)(params)
        params, ost = optim.update(ocfg, g, ost, params)
        return params, ost, loss

    for s in range(steps):
        b = classify_batch(seed, s, 64, 24, vocab=cfg.vocab)
        params, ost, loss = step(params, ost, b["tokens"], b["labels"])
    return cfg, params


def _accuracy(params, cfg, softmax, n_batches=8, seed=99):
    cfg2 = cfg.with_(softmax_impl=softmax)
    correct = total = 0
    for s in range(n_batches):
        b = classify_batch(seed, 1000 + s, 64, 24, vocab=cfg.vocab)
        lg = _logits(params, b["tokens"], cfg2)
        correct += int(jnp.sum(jnp.argmax(lg, -1) == b["labels"]))
        total += lg.shape[0]
    return correct / total


def softmax_error_metrics(impl, key=jax.random.PRNGKey(0)):
    """Distribution-level errors on attention-shaped logits."""
    z = jax.random.normal(key, (256, 128), F32) * 3.0
    s = get_softmax(impl)(z).astype(F32)
    ref = jax.nn.softmax(z, -1)
    p = s / jnp.maximum(jnp.sum(s, -1, keepdims=True), 1e-9)
    kl = jnp.sum(ref * (jnp.log(ref + 1e-12) - jnp.log(p + 1e-12)), -1)
    return dict(mean_abs=float(jnp.mean(jnp.abs(s - ref))),
                max_abs=float(jnp.max(jnp.abs(s - ref))),
                mean_kl=float(jnp.mean(kl)))


def run(report):
    cfg, params = _train_classifier()
    base = _accuracy(params, cfg, "exact")
    for impl in IMPLS:
        acc = _accuracy(params, cfg, impl)
        em = softmax_error_metrics(impl)
        report(f"table1,{impl},acc={acc:.4f},delta={acc - base:+.4f},"
               f"mean_abs={em['mean_abs']:.5f},max_abs={em['max_abs']:.4f},"
               f"kl={em['mean_kl']:.5f}")
    return base
