"""Registry-driven Pallas kernel microbench (DESIGN.md §16).

Times every kernel in ``analysis/pallas_check.default_registry()`` — the
same 10 entries the tile prover walks, so bench coverage and bounds
coverage cannot drift apart — and joins each against its XLA HLO cost:
us/call plus achieved GFLOP/s / GB/s / roofline fraction vs the TPU-v5e
bound, per (kernel, shape, format).  Results land in BENCH_kernels.json
with a full provenance stamp and a ledger row.

Absolute numbers on this container are CPU interpret-mode times — the
roofline fractions are deliberately tiny; the artifact's job is to stop
those numbers masquerading as hardware results and to give TPU runs a
trajectory to land on.
"""
from __future__ import annotations

from repro.obs import profile


def run(report, iters: int = 20, quick: bool = False):
    """All 10 registry kernels even in --quick (coverage is the contract);
    quick only drops the iteration count."""
    rows = profile.microbench(iters=3 if quick else iters, report=report)
    return {"kernels": rows}


if __name__ == "__main__":
    import argparse

    from repro.obs import ledger

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_kernels.json")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: fewer timing iters (same 10 kernels)")
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--xla-profile", default=None, metavar="DIR",
                    help="jax.profiler capture window around the bench "
                         "(xplane + trace.json.gz under DIR)")
    ap.add_argument("--ledger", default="auto",
                    help="ledger path ('auto' = next to --json, 'none' to "
                         "skip the append)")
    args = ap.parse_args()
    with profile.xla_profile(args.xla_profile):
        res = run(print, iters=args.iters, quick=args.quick)
    ledger.finalize(args.json, "kernels", res,
                    mode="smoke" if args.quick else "full",
                    ledger_path=None if args.ledger == "none"
                    else args.ledger)
    print(f"# wrote {args.json}")
