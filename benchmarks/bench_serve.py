"""Serving benchmark: continuous batching vs lockstep under ragged traffic.

Drives a Poisson-arrival workload with mixed prompt and output lengths
through ``repro.serve.scheduler`` twice — once with the ``lockstep``
admission policy (drain the slot pool between groups; the PR 2 rectangular
baseline generalized to ragged prompts) and once with ``continuous``
(admit queued requests into freed slots mid-decode).  Both runs share the
exact same jitted burst/prefill executables, so the comparison isolates the
scheduling policy: the continuous engine wins exactly as much slot-idle
time as lockstep wastes running every group to its longest member.

Reports aggregate tokens/sec, request latency p50/p99 (completion − Poisson
arrival), and mean slot occupancy; results land in ``BENCH_serve.json``
(CI runs ``--smoke`` and asserts continuous >= lockstep on tokens/sec).

Absolute numbers are CPU times (Pallas in interpreter mode; on TPU it is
the compiled path) — read the relative trends.
"""
from __future__ import annotations

import time

import jax
import numpy as np


def _build(vocab=128, n_layers=2):
    from repro.configs import get_config, smoke_config
    from repro.models import build_model
    from repro.models.layers import unbox
    cfg = smoke_config(get_config("olmo-1b")).with_(
        softmax_impl="hyft16", vocab=vocab, n_layers=n_layers)
    model = build_model(cfg)
    params = unbox(model.init(jax.random.PRNGKey(0)))
    return cfg, model, params


def make_workload(cfg, n, rng, plen, new, rate_hz):
    """``n`` requests: prompt length U[plen], output budget U[new] (the
    mixed-horizon shape lockstep handles worst), exponential interarrivals
    at ``rate_hz`` (Poisson process)."""
    from repro.serve.scheduler import Request
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, n))
    return [Request(
        rid=i,
        tokens=rng.integers(0, cfg.vocab,
                            int(rng.integers(plen[0], plen[1] + 1))).astype(
                                np.int32),
        max_new=int(rng.integers(new[0], new[1] + 1)),
        arrival=float(arrivals[i])) for i in range(n)]


def run_engine(model, params, reqs, scfg):
    from repro.serve.scheduler import SlotPoolEngine
    eng = SlotPoolEngine(model, params, scfg)
    # compile every admission/burst shape up front: admission group shapes
    # depend on wall-clock arrival timing, so an untimed warmup run would
    # not reliably cover them and a mid-run trace would pollute the timing
    eng.prewarm(max(len(r.tokens) for r in reqs))
    t0 = time.perf_counter()
    done = eng.run(reqs)
    wall = time.perf_counter() - t0
    tokens = sum(len(c.tokens) for c in done.values())
    lat = np.array([c.latency for c in done.values()])
    st = eng.stats
    occ = (st["slot_steps_active"] /
           max(1, st["burst_steps"] * scfg.n_slots))
    return {"scheduler": scfg.scheduler, "wall_s": wall, "tokens": tokens,
            "tokens_per_s": tokens / wall,
            "p50_ms": float(np.percentile(lat, 50) * 1e3),
            "p99_ms": float(np.percentile(lat, 99) * 1e3),
            "occupancy": occ, "bursts": st["bursts"],
            "prefills": st["prefills"]}


def run(report, smoke: bool = False):
    """Returns the machine-readable results dict (also printed as CSV)."""
    from repro.configs.base import ServeConfig
    cfg, model, params = _build()
    # arrival rate is set well above the service rate so a queue builds —
    # the regime where the admission policy matters (an unsaturated pool
    # admits small groups either way and the two schedulers converge)
    if smoke:
        n, plen, new, rate, slots, burst = 12, (4, 12), (4, 32), 200.0, 4, 4
    else:
        n, plen, new, rate, slots, burst = 32, (4, 16), (8, 128), 100.0, 8, 8
    rng = np.random.default_rng(0)
    reqs = make_workload(cfg, n, rng, plen, new, rate)
    max_len = plen[1] + new[1] + 1
    workload = {"requests": n, "prompt_len": list(plen), "max_new": list(new),
                "poisson_rate_hz": rate, "n_slots": slots,
                "decode_burst": burst,
                "total_tokens": sum(r.max_new for r in reqs)}
    report(f"bench_serve,workload,requests={n},prompts={plen},new={new},"
           f"slots={slots}")

    results = {"workload": workload, "engines": {}}
    for mode in ("lockstep", "continuous"):
        scfg = ServeConfig(max_len=max_len, cache_dtype="float32",
                           scheduler=mode, n_slots=slots, decode_burst=burst)
        r = run_engine(model, params, reqs, scfg)
        results["engines"][mode] = r
        report(f"bench_serve,{mode},tokens_per_s={r['tokens_per_s']:.1f},"
               f"p50_ms={r['p50_ms']:.0f},p99_ms={r['p99_ms']:.0f},"
               f"occupancy={r['occupancy']:.2f}")
    speed = (results["engines"]["continuous"]["tokens_per_s"] /
             results["engines"]["lockstep"]["tokens_per_s"])
    results["continuous_vs_lockstep"] = speed
    report(f"bench_serve,speedup,continuous_vs_lockstep={speed:.2f}")
    return results


if __name__ == "__main__":
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_serve.json")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: smaller workload, shorter horizons")
    args = ap.parse_args()
    res = run(print, smoke=args.smoke)
    with open(args.json, "w") as f:
        json.dump(res, f, indent=2)
    print(f"# wrote {args.json}")
