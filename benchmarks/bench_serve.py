"""Serving benchmark: continuous vs lockstep, paged+prefix-cache vs dense,
speculative vs plain continuous decode, chunked vs whole-prompt prefill.

Four workloads through ``repro.serve.scheduler``:

  mixed-length Poisson — the PR 3 comparison: ``lockstep`` admission (drain
      the slot pool between groups) vs ``continuous`` (admit into freed
      slots mid-decode).  Both share the same jitted burst/prefill
      executables, so the comparison isolates the scheduling policy.
  shared-prefix — N requests drawn from K distinct system prompts (a long
      shared head + a short unique tail), served by the dense slot pool and
      by the paged layout with the radix-trie prefix cache
      (``kv_layout="paged"``, ``prefix_cache=True``).  The paged engine
      admits followers by reusing the cached prefix pages and pushes only
      the unique tail through the model; the benchmark records the
      prefix-hit rate, peak pages in use, preemption count, and tokens/sec
      against the dense baseline that re-prefills every prompt in full.
  repetitive/agentic — prompts shaped like boilerplate edits / tool-call
      loops (a short "line" motif tiled several times + a unique tail),
      the high n-gram-hit-rate regime speculative decoding exists for.
      Served by plain continuous decode and by ``scheduler="spec"``
      (n-gram self-drafting, one-call verify bursts); the benchmark
      records the acceptance rate and tokens-per-model-call alongside
      tokens/sec.  Greedy outputs are identical by construction, so the
      comparison isolates the decode strategy.
  mixed long/short — short interactive prompts share the pool with long
      ones (the head-of-line-blocking regime chunked prefill exists for),
      served whole-prompt (``prefill_chunk=0``: an admitted prompt's whole
      prefill runs as one call before the next decode burst) and chunked
      (``prefill_chunk=N``: at most N prompt tokens between bursts).
      Outputs are identical; the benchmark records TTFT and
      time-between-tokens (TBT) p50/p99, where bounded prefill stalls show
      up directly as a lower TBT tail.
  chaos (``--chaos``) — the robustness contract under seeded fault
      injection (``repro.serve.chaos``, DESIGN.md §13): four serving
      configs (dense fp32, dense fp2fx8, paged+prefix, speculative) each
      run fault-free and then under a ``FaultPlan`` mixing forced
      preemptions, NaN/Inf KV poison, trie-eviction storms, page-pool
      squeezes, drafter desync, stragglers, and cancellations — with
      ``audit=True`` so pool/trie refcounts are recomputed at every
      checkpoint.  CI asserts every request reaches a DEFINITE outcome,
      non-poisoned completions are token-identical to the fault-free run,
      and the audits stayed clean.  All requests arrive at t=0 with no
      deadlines, making the scheduling sequence wall-clock-free and the
      fault replay deterministic.

Reports aggregate tokens/sec, request latency p50/p99 (completion − Poisson
arrival), TTFT/TBT percentiles, and mean slot occupancy; results land in
``BENCH_serve.json`` (CI runs ``--smoke`` and asserts continuous >=
lockstep, paged+prefix >= dense, and chunked p99 TBT < whole-prompt on
their respective workloads).

An observability section (``--trace``, DESIGN.md §15) serves traced
workloads with the span tracer + metrics JSONL export on: it measures the
tracer's wall-clock overhead (CI asserts < 5%), writes a Perfetto-loadable
``TRACE_serve.json`` covering the span taxonomy, reconciles the metrics
registry against the post-hoc ``Completion`` records, and reports the
hybrid-format numeric telemetry (softmax exponent range, fp2fx8 scale
histogram, int8 saturation) from a ``telemetry=True`` fp2fx8 engine under
NaN poison — including the numeric stats attached to each quarantine.

Absolute numbers are CPU times (Pallas in interpreter mode; on TPU it is
the compiled path) — read the relative trends.  Every engine's one-time
warm-up (``prewarm``'s executable compilation plus first-run runtime setup:
XLA thread pools, allocator arenas) is timed explicitly and reported as
``warmup_s`` per engine, so the serving wall-clock numbers exclude it and
sections stay comparable whether run standalone (``--prefix-only`` /
``--spec-only`` / ``--chunked-only``, the CI jobs' shape) or in one sweep;
``--merge`` lets standalone runs update one shared JSON.
"""
from __future__ import annotations

import time

import jax
import numpy as np


def _build(vocab=128, n_layers=2):
    from repro.configs import get_config, smoke_config
    from repro.models import build_model
    from repro.models.layers import unbox
    cfg = smoke_config(get_config("olmo-1b")).with_(
        softmax_impl="hyft16", vocab=vocab, n_layers=n_layers)
    model = build_model(cfg)
    params = unbox(model.init(jax.random.PRNGKey(0)))
    return cfg, model, params


def make_workload(cfg, n, rng, plen, new, rate_hz):
    """``n`` requests: prompt length U[plen], output budget U[new] (the
    mixed-horizon shape lockstep handles worst), exponential interarrivals
    at ``rate_hz`` (Poisson process)."""
    from repro.serve.scheduler import Request
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, n))
    return [Request(
        rid=i,
        tokens=rng.integers(0, cfg.vocab,
                            int(rng.integers(plen[0], plen[1] + 1))).astype(
                                np.int32),
        max_new=int(rng.integers(new[0], new[1] + 1)),
        arrival=float(arrivals[i])) for i in range(n)]


def make_prefix_workload(cfg, n, k_prompts, rng, prefix_len, tail, new,
                         rate_hz):
    """``n`` requests over ``k_prompts`` distinct system prompts: each
    prompt is a shared ``prefix_len``-token head + a ``tail``-token unique
    suffix — the shape a prefix cache exists for (the dense baseline
    re-prefills the shared head for every request)."""
    from repro.serve.scheduler import Request
    heads = [rng.integers(0, cfg.vocab, prefix_len).astype(np.int32)
             for _ in range(k_prompts)]
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, n))
    return [Request(
        rid=i,
        tokens=np.concatenate(
            [heads[i % k_prompts],
             rng.integers(0, cfg.vocab, tail).astype(np.int32)]),
        max_new=int(rng.integers(new[0], new[1] + 1)),
        arrival=float(arrivals[i])) for i in range(n)]


def make_repetitive_workload(cfg, n, rng, motif_len, reps, tail, new,
                             rate_hz):
    """``n`` requests with code-ish repetitive prompts: a ``motif_len``
    "line" tiled ``reps`` times + a ``tail``-token unique suffix.  The
    trailing n-gram of such a context almost always recurs earlier, so the
    prompt-lookup drafter stays hot — the agentic/templated-output regime
    speculative decoding targets."""
    from repro.serve.scheduler import Request
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, n))
    reqs = []
    for i in range(n):
        motif = rng.integers(0, cfg.vocab, motif_len).astype(np.int32)
        toks = np.concatenate(
            [np.tile(motif, reps),
             rng.integers(0, cfg.vocab, tail).astype(np.int32)])
        reqs.append(Request(rid=i, tokens=toks,
                            max_new=int(rng.integers(new[0], new[1] + 1)),
                            arrival=float(arrivals[i])))
    return reqs


def _latency_stats(done):
    """TTFT (first token − arrival) and TBT (successive token-emission
    gaps, pooled across requests) percentiles, in milliseconds.  Requests
    that never emitted a token (cancelled / failed before their first
    emission) have ``ttft is None`` and are skipped."""
    ttft = np.array([c.ttft for c in done.values() if c.ttft is not None])
    if ttft.size == 0:
        ttft = np.zeros(1)
    gaps = [np.diff(c.token_times) for c in done.values()
            if len(c.token_times) > 1]
    tbt = np.concatenate(gaps) if gaps else np.zeros(1)
    return {"ttft_p50_ms": float(np.percentile(ttft, 50) * 1e3),
            "ttft_p99_ms": float(np.percentile(ttft, 99) * 1e3),
            "tbt_p50_ms": float(np.percentile(tbt, 50) * 1e3),
            "tbt_p99_ms": float(np.percentile(tbt, 99) * 1e3)}


def run_engine(model, params, reqs, scfg, obs=None):
    """Serve ``reqs`` on a prewarmed engine; returns (metrics dict,
    completions dict) — callers compare completions across engines.

    When the engine owns its Obs bundle (``obs=None``) the §16 cost book is
    switched on: prewarm records each executable's ``cost_analysis()``
    FLOPs/bytes and the serving loop joins them with measured dispatch
    walls, reported as the ``roofline`` block per engine."""
    from repro.serve.scheduler import SlotPoolEngine
    eng = SlotPoolEngine(model, params, scfg, obs=obs)
    if obs is None:
        eng.obs.profile.enabled = True  # before prewarm: that's record time
    # compile every admission/burst shape up front: admission group shapes
    # depend on wall-clock arrival timing, so an untimed warmup run would
    # not reliably cover them and a mid-run trace would pollute the timing
    t_w = time.perf_counter()
    eng.prewarm(max(len(r.tokens) for r in reqs))
    warmup = time.perf_counter() - t_w
    t0 = time.perf_counter()
    done = eng.run(reqs)
    wall = time.perf_counter() - t0
    tokens = sum(len(c.tokens) for c in done.values())
    lat = np.array([c.latency for c in done.values()])
    st = eng.stats
    occ = (st["slot_steps_active"] /
           max(1, st["burst_steps"] * scfg.n_slots))
    out = {"scheduler": scfg.scheduler, "kv_layout": scfg.kv_layout,
           "prefill_chunk": scfg.prefill_chunk,
           "warmup_s": warmup, "wall_s": wall, "tokens": tokens,
           "tokens_per_s": tokens / wall,
           "p50_ms": float(np.percentile(lat, 50) * 1e3),
           "p99_ms": float(np.percentile(lat, 99) * 1e3),
           "occupancy": occ, "bursts": st["bursts"],
           "prefills": st["prefills"],
           "prefill_tokens": st["prefill_tokens"],
           "model_calls": st["model_calls"],
           "tokens_per_model_call": (st["tokens_emitted"] /
                                     max(1, st["model_calls"]))}
    # cost-analysis join per dispatched executable (only rows that were
    # actually observed carry achieved/roofline columns)
    roof = {name: r for name, r in eng.obs.profile.summary().items()
            if "roofline_fraction" in r}
    if roof:
        out["roofline"] = roof
    out.update(_latency_stats(done))
    if scfg.scheduler == "spec":
        out.update(
            acceptance_rate=(st["accepted_tokens"] /
                             max(1, st["draft_tokens"])),
            draft_tokens=st["draft_tokens"],
            accepted_tokens=st["accepted_tokens"],
            spec_steps=st["spec_steps"])
    if scfg.kv_layout == "paged":
        out.update(
            prefix_hit_rate=st["cached_tokens"] / max(1, st["prompt_tokens"]),
            cached_tokens=st["cached_tokens"],
            pages_peak=st["pages_peak"],
            preemptions=st["preemptions"])
    return out, done


def _report_roofline(report, tag, r):
    """One achieved-vs-peak line per executable an engine dispatched."""
    for name, j in r.get("roofline", {}).items():
        report(f"bench_serve_roofline,{tag},exe={name},"
               f"calls={j['calls']},gflops={j['achieved_gflops']:.3f},"
               f"gbps={j['achieved_gbps']:.3f},"
               f"frac={j['roofline_fraction']:.2e},"
               f"bound={j['bound_dominant']}")


def make_mixed_workload(cfg, n, rng, short, long_, frac_long, new, rate_hz):
    """``n`` requests mixing short interactive prompts (length U[short])
    with long ones (U[long_], probability ``frac_long``) — the
    head-of-line-blocking shape where a long arrival's whole-prompt prefill
    stalls every in-flight decode, which chunked prefill bounds."""
    from repro.serve.scheduler import Request
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, n))
    reqs = []
    for i in range(n):
        plen = (int(rng.integers(long_[0], long_[1] + 1))
                if rng.random() < frac_long
                else int(rng.integers(short[0], short[1] + 1)))
        reqs.append(Request(
            rid=i, tokens=rng.integers(0, cfg.vocab, plen).astype(np.int32),
            max_new=int(rng.integers(new[0], new[1] + 1)),
            arrival=float(arrivals[i])))
    return reqs


def run(report, smoke: bool = False, prefix_only: bool = False,
        spec_only: bool = False, chunked_only: bool = False,
        chaos_only: bool = False, obs_only: bool = False,
        trace_out: str = "TRACE_serve.json",
        metrics_out: str = "METRICS_serve.jsonl"):
    """Returns the machine-readable results dict (also printed as CSV).

    ``prefix_only`` runs just the shared-prefix section, ``spec_only`` just
    the repetitive/speculative section, ``chunked_only`` just the mixed
    long/short chunked-prefill section, ``chaos_only`` just the
    fault-injection robustness section, and ``obs_only`` just the
    observability section — the CI jobs each assert on one comparison and
    need not pay for the others.
    """
    from repro.configs.base import ServeConfig
    cfg, model, params = _build()
    if chaos_only:
        return _run_chaos(report, {}, cfg, model, params, smoke)
    if obs_only:
        return _run_obs(report, {}, cfg, model, params, smoke,
                        trace_out=trace_out, metrics_out=metrics_out)
    # arrival rate is set well above the service rate so a queue builds —
    # the regime where the admission policy matters (an unsaturated pool
    # admits small groups either way and the two schedulers converge)
    if smoke:
        n, plen, new, rate, slots, burst = 12, (4, 12), (4, 32), 200.0, 4, 4
    else:
        n, plen, new, rate, slots, burst = 32, (4, 16), (8, 128), 100.0, 8, 8
    # one dedicated rng per section: a section's workload is identical
    # whether it runs standalone (--prefix-only/--spec-only, the CI jobs)
    # or as part of the full sweep, so --merge'd JSONs stay comparable
    rng = np.random.default_rng(0)
    results: dict = {}
    if chunked_only:
        return _run_chunked(report, results, cfg, model, params,
                            np.random.default_rng(3), smoke)
    if not prefix_only and not spec_only:
        reqs = make_workload(cfg, n, rng, plen, new, rate)
        max_len = plen[1] + new[1] + 1
        results["workload"] = {
            "requests": n, "prompt_len": list(plen), "max_new": list(new),
            "poisson_rate_hz": rate, "n_slots": slots,
            "decode_burst": burst,
            "total_tokens": sum(r.max_new for r in reqs)}
        report(f"bench_serve,workload,requests={n},prompts={plen},"
               f"new={new},slots={slots}")
        results["engines"] = {}
        for mode in ("lockstep", "continuous"):
            scfg = ServeConfig(max_len=max_len, cache_dtype="float32",
                               scheduler=mode, n_slots=slots,
                               decode_burst=burst)
            r, _ = run_engine(model, params, reqs, scfg)
            results["engines"][mode] = r
            report(f"bench_serve,{mode},"
                   f"tokens_per_s={r['tokens_per_s']:.1f},"
                   f"p50_ms={r['p50_ms']:.0f},p99_ms={r['p99_ms']:.0f},"
                   f"occupancy={r['occupancy']:.2f}")
            _report_roofline(report, mode, r)
        speed = (results["engines"]["continuous"]["tokens_per_s"] /
                 results["engines"]["lockstep"]["tokens_per_s"])
        results["continuous_vs_lockstep"] = speed
        report(f"bench_serve,speedup,continuous_vs_lockstep={speed:.2f}")

    # ---- shared-prefix workload: paged + prefix cache vs dense ----------
    if spec_only:
        return _run_spec(report, results, cfg, model, params,
                         np.random.default_rng(2), smoke, burst)
    if smoke:
        pn, kpr, pref, tail, pnew, prate, pslots = 12, 2, 48, 4, (4, 12), \
            200.0, 4
    else:
        pn, kpr, pref, tail, pnew, prate, pslots = 32, 3, 96, 8, (8, 32), \
            100.0, 8
    prng = np.random.default_rng(1)
    preqs = make_prefix_workload(cfg, pn, kpr, prng, pref, tail, pnew,
                                 prate)
    pmax_len = pref + tail + pnew[1] + 1
    results["prefix_workload"] = {
        "requests": pn, "distinct_prompts": kpr, "prefix_len": pref,
        "tail_len": tail, "max_new": list(pnew), "poisson_rate_hz": prate,
        "n_slots": pslots, "page_size": 16,
        "total_tokens": sum(r.max_new for r in preqs)}
    report(f"bench_serve,prefix_workload,requests={pn},prompts={kpr},"
           f"prefix={pref},tail={tail}")
    results["prefix_engines"] = {}
    for name, kw in (("dense", dict(kv_layout="dense")),
                     ("paged_prefix", dict(kv_layout="paged", page_size=16,
                                           prefix_cache=True))):
        scfg = ServeConfig(max_len=pmax_len, cache_dtype="float32",
                           scheduler="continuous", n_slots=pslots,
                           decode_burst=burst, **kw)
        r, _ = run_engine(model, params, preqs, scfg)
        results["prefix_engines"][name] = r
        extra = (f",hit_rate={r['prefix_hit_rate']:.2f},"
                 f"pages_peak={r['pages_peak']},"
                 f"preemptions={r['preemptions']}"
                 if name == "paged_prefix" else "")
        report(f"bench_serve,prefix_{name},"
               f"tokens_per_s={r['tokens_per_s']:.1f},"
               f"prefill_tokens={r['prefill_tokens']}{extra}")
        _report_roofline(report, f"prefix_{name}", r)
    pspeed = (results["prefix_engines"]["paged_prefix"]["tokens_per_s"] /
              results["prefix_engines"]["dense"]["tokens_per_s"])
    results["paged_prefix_vs_dense"] = pspeed
    report(f"bench_serve,speedup,paged_prefix_vs_dense={pspeed:.2f}")
    if prefix_only:
        return results
    results = _run_spec(report, results, cfg, model, params,
                        np.random.default_rng(2), smoke, burst)
    return _run_chunked(report, results, cfg, model, params,
                        np.random.default_rng(3), smoke)


def _run_spec(report, results, cfg, model, params, rng, smoke, burst):
    """Repetitive/agentic workload: speculative vs plain continuous decode.

    Both engines share admission policy, slot count, and layout — the only
    difference is the decode strategy, so tokens/sec isolates what the
    accepted drafts buy and ``tokens_per_model_call`` shows the
    amortization directly.
    """
    from repro.configs.base import ServeConfig
    # arrival rate is set high enough that BOTH engines run compute-bound:
    # spec drains the queue fast enough that at the other sections' rates
    # it goes arrival-limited and the ratio collapses toward 1 by
    # construction, not by decode speed
    if smoke:
        sn, motif, reps, stail, snew, srate, sslots, skk = \
            12, 6, 4, 4, (8, 24), 400.0, 4, 4
    else:
        sn, motif, reps, stail, snew, srate, sslots, skk = \
            32, 8, 6, 8, (16, 64), 500.0, 8, 4
    sreqs = make_repetitive_workload(cfg, sn, rng, motif, reps, stail, snew,
                                     srate)
    smax_len = motif * reps + stail + snew[1] + 1
    results["spec_workload"] = {
        "requests": sn, "motif_len": motif, "reps": reps, "tail_len": stail,
        "max_new": list(snew), "poisson_rate_hz": srate, "n_slots": sslots,
        "draft_k": skk, "total_tokens": sum(r.max_new for r in sreqs)}
    report(f"bench_serve,spec_workload,requests={sn},motif={motif}x{reps},"
           f"tail={stail},draft_k={skk}")
    results["spec_engines"] = {}
    for name, kw in (("baseline", dict(scheduler="continuous")),
                     ("spec", dict(scheduler="spec", draft_k=skk))):
        scfg = ServeConfig(max_len=smax_len, cache_dtype="float32",
                           n_slots=sslots, decode_burst=burst, **kw)
        r, _ = run_engine(model, params, sreqs, scfg)
        results["spec_engines"][name] = r
        extra = (f",acceptance={r['acceptance_rate']:.2f},"
                 f"tok_per_call={r['tokens_per_model_call']:.2f}"
                 if name == "spec" else
                 f",tok_per_call={r['tokens_per_model_call']:.2f}")
        report(f"bench_serve,spec_{name},"
               f"tokens_per_s={r['tokens_per_s']:.1f},"
               f"model_calls={r['model_calls']}{extra}")
        _report_roofline(report, f"spec_{name}", r)
    sspeed = (results["spec_engines"]["spec"]["tokens_per_s"] /
              results["spec_engines"]["baseline"]["tokens_per_s"])
    results["spec_vs_baseline"] = sspeed
    report(f"bench_serve,speedup,spec_vs_baseline={sspeed:.2f}")
    return results


def _run_chunked(report, results, cfg, model, params, rng, smoke):
    """Mixed long/short workload: chunked vs whole-prompt prefill.

    Same admission policy, slots, and decode bursts — the only difference
    is ``prefill_chunk``, so the TBT tail isolates what bounding the
    per-burst prefill stall buys: in whole-prompt mode a long arrival's
    entire prefill runs between two decode bursts and every in-flight
    request's inter-token gap eats it; chunked mode caps that stall at one
    chunk's worth of tokens.  Outputs are identical by construction (the
    chunk split is invisible to the arithmetic) — recorded in the results
    so CI can assert it.
    """
    from repro.configs.base import ServeConfig
    if smoke:
        cn, cshort, clong, cfrac, cnew, crate, cslots, cburst, chunk = \
            10, (3, 8), (48, 72), 0.3, (8, 24), 150.0, 4, 4, 8
    else:
        cn, cshort, clong, cfrac, cnew, crate, cslots, cburst, chunk = \
            24, (4, 12), (96, 128), 0.3, (16, 48), 80.0, 8, 8, 16
    creqs = make_mixed_workload(cfg, cn, rng, cshort, clong, cfrac, cnew,
                                crate)
    cmax_len = clong[1] + cnew[1] + 1
    results["chunked_workload"] = {
        "requests": cn, "short_len": list(cshort), "long_len": list(clong),
        "frac_long": cfrac, "max_new": list(cnew), "poisson_rate_hz": crate,
        "n_slots": cslots, "decode_burst": cburst, "prefill_chunk": chunk,
        "total_tokens": sum(r.max_new for r in creqs)}
    report(f"bench_serve,chunked_workload,requests={cn},short={cshort},"
           f"long={clong},chunk={chunk}")
    results["chunked_engines"] = {}
    outs = {}
    for name, pchunk in (("whole_prompt", 0), ("chunked", chunk)):
        scfg = ServeConfig(max_len=cmax_len, cache_dtype="float32",
                           scheduler="continuous", n_slots=cslots,
                           decode_burst=cburst, prefill_chunk=pchunk)
        r, done = run_engine(model, params, creqs, scfg)
        results["chunked_engines"][name] = r
        outs[name] = {rid: c.tokens for rid, c in done.items()}
        report(f"bench_serve,chunked_{name},"
               f"tokens_per_s={r['tokens_per_s']:.1f},"
               f"ttft_p50_ms={r['ttft_p50_ms']:.0f},"
               f"ttft_p99_ms={r['ttft_p99_ms']:.0f},"
               f"tbt_p50_ms={r['tbt_p50_ms']:.1f},"
               f"tbt_p99_ms={r['tbt_p99_ms']:.1f}")
    results["chunked_outputs_equal"] = outs["chunked"] == outs["whole_prompt"]
    ratio = (results["chunked_engines"]["whole_prompt"]["tbt_p99_ms"] /
             max(1e-9, results["chunked_engines"]["chunked"]["tbt_p99_ms"]))
    results["whole_prompt_vs_chunked_tbt_p99"] = ratio
    report(f"bench_serve,chunked,outputs_equal="
           f"{results['chunked_outputs_equal']},"
           f"tbt_p99_whole_over_chunked={ratio:.2f}")
    return results


def _run_obs(report, results, cfg, model, params, smoke,
             trace_out="TRACE_serve.json",
             metrics_out="METRICS_serve.jsonl"):
    """Observability section (DESIGN.md §15): tracer overhead, trace span
    coverage, metrics↔completions reconciliation, numeric telemetry.

    Four measurements:

      overhead — the SAME deterministic workload (every arrival at t=0, so
          the admission sequence is wall-clock-free) served with the tracer
          off and on, interleaved, best-of-3 fresh engines each; CI asserts
          the traced wall is < 5% over the untraced one.
      coverage — one shared ``Obs`` bundle (tracer + metrics JSONL export)
          traces a paged+prefix engine under a chaos plan (forced
          preemptions, eviction storms, pool squeezes, NaN poison) and then
          a speculative engine, so the single ``TRACE_serve.json`` covers
          admit / prefill_chunk / decode_burst / spec_verify / compile /
          preempt / evict / quarantine.
      reconciliation — per-engine (the metrics carry scheduler+family
          labels) the registry's token counter and TTFT/TBT histograms are
          checked against the post-hoc ``Completion`` records: counts and
          sums must match exactly, percentiles to the sketch's ~2.5%
          relative error.
      numerics — a dense fp2fx8 engine with ``telemetry=True`` under a
          NaN-poison plan: softmax-input exponent range pre/post
          max-subtraction, KV scale histogram, int8 saturation, convert
          volume — and every quarantine annotated with the numeric stats
          in force when it fired.
    """
    import os

    from repro.configs.base import ServeConfig
    from repro.obs import Obs
    from repro.serve.chaos import ChaosMonkey, FaultPlan
    from repro.serve.scheduler import Request, SlotPoolEngine

    if smoke:
        n, slots, burst, plen, new = 10, 4, 4, (4, 12), (6, 16)
    else:
        n, slots, burst, plen, new = 24, 6, 4, (4, 16), (8, 32)
    rng = np.random.default_rng(5)
    reqs = [Request(
        rid=i,
        tokens=rng.integers(0, cfg.vocab,
                            int(rng.integers(plen[0], plen[1] + 1))).astype(
                                np.int32),
        max_new=int(rng.integers(new[0], new[1] + 1)), arrival=0.0)
        for i in range(n)]
    max_len = plen[1] + new[1] + 1
    base = dict(max_len=max_len, cache_dtype="float32",
                scheduler="continuous", n_slots=slots, decode_burst=burst)
    obs_res: dict = {"workload": {"requests": n, "n_slots": slots,
                                  "decode_burst": burst,
                                  "prompt_len": list(plen),
                                  "max_new": list(new)}}

    # ---- tracer overhead: off vs on, interleaved, best-of-3 -------------
    def _timed(obs):
        eng = SlotPoolEngine(model, params, ServeConfig(**base), obs=obs)
        eng.prewarm(max(len(r.tokens) for r in reqs))
        t0 = time.perf_counter()
        done = eng.run(reqs)
        return time.perf_counter() - t0, done

    w_off, w_on = [], []
    for _ in range(3):
        w, _d = _timed(None)
        w_off.append(w)
        w, _d = _timed(Obs.enabled())
        w_on.append(w)
    overhead = min(w_on) / max(1e-9, min(w_off)) - 1.0
    obs_res["overhead"] = {"wall_off_s": min(w_off), "wall_on_s": min(w_on),
                           "frac": overhead}
    report(f"bench_serve,obs_overhead,off_s={min(w_off):.3f},"
           f"on_s={min(w_on):.3f},frac={overhead:+.3f}")

    # ---- span coverage + metrics reconciliation (one shared bundle) -----
    if os.path.exists(metrics_out):
        os.remove(metrics_out)  # JSONL export appends
    obs = Obs.enabled(metrics_path=metrics_out, snapshot_every_s=0.25)
    prng = np.random.default_rng(6)
    preqs = make_prefix_workload(cfg, n, 2, prng, 16, 6, new, 10000.0)
    plan = FaultPlan(seed=21, preempt_rate=0.40, evict_storm_rate=0.20,
                     squeeze_rate=0.20, squeeze_frac=0.5, squeeze_hold=2,
                     nan_kv_rate=0.15, max_faults=16)
    scfg_p = ServeConfig(max_len=16 + 6 + new[1] + 1, cache_dtype="float32",
                         scheduler="continuous", n_slots=slots,
                         decode_burst=burst, kv_layout="paged", page_size=8,
                         prefix_cache=True, prefill_chunk=8, audit=True)
    eng_p = SlotPoolEngine(model, params, scfg_p, chaos=ChaosMonkey(plan),
                           obs=obs)
    eng_p.prewarm(max(len(r.tokens) for r in preqs))
    done_p = eng_p.run(preqs)
    sreqs = make_repetitive_workload(cfg, n, np.random.default_rng(7), 6, 4,
                                     4, new, 10000.0)
    scfg_s = ServeConfig(max_len=6 * 4 + 4 + new[1] + 1,
                         cache_dtype="float32", scheduler="spec", draft_k=4,
                         n_slots=slots, decode_burst=burst)
    eng_s = SlotPoolEngine(model, params, scfg_s, obs=obs)
    eng_s.prewarm(max(len(r.tokens) for r in sreqs))
    done_s = eng_s.run(sreqs)
    obs.tracer.write(trace_out)
    kinds = sorted(obs.tracer.span_kinds())
    obs_res["trace"] = {"path": trace_out, "events": len(obs.tracer.events),
                        "span_kinds": kinds}
    report(f"bench_serve,obs_trace,events={len(obs.tracer.events)},"
           f"kinds={'|'.join(kinds)}")

    def _reconcile(scfg, done):
        lab = dict(scheduler=scfg.scheduler, family=cfg.family)
        m = obs.metrics
        tok = m.find("serve.tokens_emitted", **lab).value
        actual = sum(len(c.tokens) for c in done.values())
        ttfts = np.array([c.ttft for c in done.values()
                          if c.ttft is not None])
        gaps = [np.diff(c.token_times) for c in done.values()
                if len(c.token_times) > 1]
        tbts = np.concatenate(gaps) if gaps else np.zeros(0)
        out = {"metric_tokens_emitted": tok, "completion_tokens": actual,
               "tokens_match": tok == actual}
        for key, vals in (("ttft", ttfts), ("tbt", tbts)):
            h = m.find(f"serve.{key}_s", **lab)
            s = h.summary() if h is not None else {"count": 0, "sum": 0.0,
                                                   "p50": 0.0}
            out[key] = {
                "metric_count": s["count"], "posthoc_count": int(vals.size),
                "metric_sum_s": s["sum"],
                "posthoc_sum_s": float(vals.sum()),
                "metric_p50_s": s["p50"],
                "posthoc_p50_s": float(np.percentile(vals, 50))
                if vals.size else 0.0}
        return out

    obs_res["reconcile"] = {"paged_chaos": _reconcile(scfg_p, done_p),
                            "spec": _reconcile(scfg_s, done_s)}
    with open(metrics_out) as f:
        obs_res["metrics_snapshots"] = sum(1 for _ in f)
    for name, r in obs_res["reconcile"].items():
        report(f"bench_serve,obs_reconcile_{name},"
               f"metric_tokens={r['metric_tokens_emitted']},"
               f"completion_tokens={r['completion_tokens']},"
               f"ttft_n={r['ttft']['metric_count']}/"
               f"{r['ttft']['posthoc_count']},"
               f"tbt_n={r['tbt']['metric_count']}/"
               f"{r['tbt']['posthoc_count']}")

    # ---- hybrid-format numeric telemetry under NaN poison ---------------
    nplan = FaultPlan(seed=22, nan_kv_rate=0.25, max_faults=6)
    scfg_n = ServeConfig(max_len=max_len, cache_dtype="fp2fx8",
                         scheduler="continuous", n_slots=slots,
                         decode_burst=burst, telemetry=True)
    eng_n = SlotPoolEngine(model, params, scfg_n,
                           chaos=ChaosMonkey(nplan), obs=Obs())
    eng_n.prewarm(max(len(r.tokens) for r in reqs))
    done_n = eng_n.run(reqs)
    num = eng_n.obs.numerics.summary()
    obs_res["numerics"] = num
    obs_res["numerics"]["ok"] = sum(1 for c in done_n.values() if c.ok)
    obs_res["numerics"]["quarantines"] = eng_n.stats["quarantines"]
    report(f"bench_serve,obs_numerics,z_max={num.get('z_max')},"
           f"zsub_min={num.get('zsub_min')},"
           f"kv_saturation_rate={num.get('kv_saturation_rate', 0):.4f},"
           f"converts={num.get('converts', 0)},"
           f"quarantine_events={len(num.get('quarantine_events', []))}")
    results["obs"] = obs_res
    return results


def _run_chaos(report, results, cfg, model, params, smoke):
    """Fault-injection robustness section (DESIGN.md §13).

    Each serving config runs the SAME workload twice on fresh engines: once
    fault-free (the oracle) and once with a seeded :class:`FaultPlan` and
    ``audit=True``.  The contract under test:

      definite   — every submitted rid ends with exactly one Completion
                   (finished, cancelled, or a structured failure) — no
                   hangs, no silently dropped requests.
      identical  — every ok completion whose KV was never poisoned emits
                   tokens identical to the fault-free run (preemptions,
                   evictions, squeezes, junk drafts, and stragglers are
                   invisible to the arithmetic).  Poisoned rids recover
                   through quarantine -> re-prefill and usually ALSO match
                   (reported separately as ``poisoned_match``) but the
                   strict gate excludes them: the fp32 retry rung of the
                   degradation ladder is allowed to differ.
      audited    — pool/trie refcounts recomputed from live slots + trie
                   edges at every admission/finish/preemption checkpoint;
                   any drift raises AuditError and fails the bench.

    Every request arrives at t=0 with no deadline, so the scheduling
    sequence is wall-clock-free and a fixed seed replays identical faults.
    """
    from repro.configs.base import ServeConfig
    from repro.serve.chaos import ChaosMonkey, FaultPlan
    from repro.serve.scheduler import Request, SlotPoolEngine

    if smoke:
        n, slots, burst, head, tail, new = 10, 4, 4, 16, (3, 6), (6, 16)
    else:
        n, slots, burst, head, tail, new = 20, 6, 4, 24, (4, 10), (8, 32)

    def prefix_reqs():
        # two shared 'system prompt' heads + unique tails: populates the
        # radix trie (so eviction storms have something to evict) while
        # keeping prompts short; all-zero arrivals for determinism
        r = np.random.default_rng(7)
        heads = [r.integers(0, cfg.vocab, head).astype(np.int32)
                 for _ in range(2)]
        return [Request(
            rid=i,
            tokens=np.concatenate(
                [heads[i % 2],
                 r.integers(0, cfg.vocab,
                            int(r.integers(tail[0], tail[1] + 1))).astype(
                                np.int32)]),
            max_new=int(r.integers(new[0], new[1] + 1)),
            arrival=0.0) for i in range(n)]

    def repetitive_reqs():
        # tiled-motif prompts keep the n-gram drafter hot so the
        # drafter-desync fault actually has drafts to corrupt
        r = np.random.default_rng(8)
        reqs = []
        for i in range(n):
            motif = r.integers(0, cfg.vocab, 6).astype(np.int32)
            toks = np.concatenate(
                [np.tile(motif, 4),
                 r.integers(0, cfg.vocab, 4).astype(np.int32)])
            reqs.append(Request(rid=i, tokens=toks,
                                max_new=int(r.integers(new[0], new[1] + 1)),
                                arrival=0.0))
        return reqs

    configs = [
        ("dense_fp32", prefix_reqs,
         dict(cache_dtype="float32", scheduler="continuous"),
         FaultPlan(seed=11, preempt_rate=0.15, nan_kv_rate=0.10,
                   cancel_rate=0.04, straggle_rate=0.10, straggle_s=0.01,
                   max_faults=6)),
        # fp2fx8: int8 raws cannot hold a NaN, so the poison lands in the
        # fp32 scale rows — the hybrid-format silent-corruption shape the
        # numeric guards exist for
        ("dense_fp2fx8", prefix_reqs,
         dict(cache_dtype="fp2fx8", scheduler="continuous"),
         FaultPlan(seed=12, preempt_rate=0.10, nan_kv_rate=0.15,
                   max_faults=6)),
        ("paged_prefix", prefix_reqs,
         dict(cache_dtype="float32", scheduler="continuous",
              kv_layout="paged", page_size=8, prefix_cache=True),
         FaultPlan(seed=13, preempt_rate=0.10, evict_storm_rate=0.15,
                   squeeze_rate=0.15, squeeze_frac=0.5, squeeze_hold=2,
                   nan_kv_rate=0.10, cancel_rate=0.04, max_faults=8)),
        ("spec", repetitive_reqs,
         dict(cache_dtype="float32", scheduler="spec", draft_k=4),
         FaultPlan(seed=14, drafter_junk_rate=0.4, preempt_rate=0.10,
                   cancel_rate=0.04, max_faults=8)),
    ]

    def _serve(scfg, reqs, plan=None):
        monkey = ChaosMonkey(plan) if plan is not None else None
        eng = SlotPoolEngine(model, params, scfg, chaos=monkey)
        t_w = time.perf_counter()
        eng.prewarm(max(len(r.tokens) for r in reqs))
        warmup = time.perf_counter() - t_w
        t0 = time.perf_counter()
        done = eng.run(reqs)
        return done, eng, monkey, time.perf_counter() - t0, warmup

    results["chaos"] = {
        "workload": {"requests": n, "n_slots": slots, "decode_burst": burst,
                     "prefix_head": head, "tail_len": list(tail),
                     "max_new": list(new)},
        "configs": {}}
    report(f"bench_serve,chaos_workload,requests={n},slots={slots},"
           f"head={head},tail={tail}")
    for name, mk, kw, plan in configs:
        reqs = mk()
        max_len = max(len(r.tokens) + r.max_new for r in reqs) + 1
        scfg = ServeConfig(max_len=max_len, n_slots=slots,
                           decode_burst=burst, audit=True, **kw)
        base_done, _, _, _, _ = _serve(scfg, reqs)
        done, eng, monkey, wall, warmup = _serve(scfg, reqs, plan)
        rids = {r.rid for r in reqs}
        definite = set(done) == rids
        oks = {rid: c for rid, c in done.items() if c.ok}
        clean = {rid: c for rid, c in oks.items()
                 if rid not in monkey.faulted_rids}
        match = all(c.tokens == base_done[rid].tokens
                    for rid, c in clean.items())
        poisoned = {rid: c for rid, c in oks.items()
                    if rid in monkey.faulted_rids}
        poisoned_match = all(c.tokens == base_done[rid].tokens
                             for rid, c in poisoned.items())
        st = eng.stats
        r = {"requests": n, "ok": len(oks),
             "cancelled": sum(1 for c in done.values() if c.cancelled),
             "failed": sum(1 for c in done.values()
                           if c.failure is not None),
             "definite": definite, "outputs_match": match,
             "poisoned": len(poisoned), "poisoned_match": poisoned_match,
             "faults": monkey.summary(), "audits": st["audits"],
             "quarantines": st["quarantines"],
             "fp32_retries": st["fp32_retries"],
             "preemptions": st["preemptions"], "wall_s": wall,
             "warmup_s": warmup}
        results["chaos"]["configs"][name] = r
        report(f"bench_serve,chaos_{name},ok={len(oks)}/{n},"
               f"cancelled={r['cancelled']},failed={r['failed']},"
               f"faults={monkey.n_faults},quarantines={r['quarantines']},"
               f"definite={definite},outputs_match={match},"
               f"audits={r['audits']}")
    return results


if __name__ == "__main__":
    import argparse
    import json
    import os

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_serve.json")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: smaller workload, shorter horizons")
    ap.add_argument("--prefix-only", action="store_true",
                    help="run only the shared-prefix (paged vs dense) "
                         "section, skipping the Poisson scheduler comparison")
    ap.add_argument("--spec-only", action="store_true",
                    help="run only the repetitive-workload (speculative vs "
                         "continuous) section")
    ap.add_argument("--chunked-only", action="store_true",
                    help="run only the mixed long/short-prompt (chunked vs "
                         "whole-prompt prefill) section")
    ap.add_argument("--chaos", action="store_true",
                    help="run only the fault-injection robustness section "
                         "(seeded FaultPlan per serving config, audits on)")
    ap.add_argument("--trace", action="store_true",
                    help="run only the observability section: tracer "
                         "overhead, Perfetto trace + metrics JSONL export, "
                         "metrics reconciliation, fp2fx8 numeric telemetry")
    ap.add_argument("--trace-out", default="TRACE_serve.json",
                    help="Chrome trace-event JSON output path (--trace)")
    ap.add_argument("--metrics-out", default="METRICS_serve.jsonl",
                    help="metrics JSONL snapshot output path (--trace)")
    ap.add_argument("--merge", action="store_true",
                    help="update an existing --json file in place (a "
                         "section-only run keeps the other sections' "
                         "results, so each section can be measured in its "
                         "own fresh process)")
    ap.add_argument("--xla-profile", default=None, metavar="DIR",
                    help="jax.profiler capture window around the bench "
                         "(xplane + trace.json.gz under DIR)")
    ap.add_argument("--ledger", default="auto",
                    help="ledger path ('auto' = next to --json, 'none' to "
                         "skip the append)")
    args = ap.parse_args()
    from repro.obs import ledger, profile
    with profile.xla_profile(args.xla_profile):
        res = run(print, smoke=args.smoke, prefix_only=args.prefix_only,
                  spec_only=args.spec_only, chunked_only=args.chunked_only,
                  chaos_only=args.chaos, obs_only=args.trace,
                  trace_out=args.trace_out, metrics_out=args.metrics_out)
    out: dict = {}
    if args.merge and os.path.exists(args.json):
        with open(args.json) as f:
            out = json.load(f)
    out.update(res)
    out.pop("provenance", None)  # re-stamped below: merged result is new
    ledger.finalize(args.json, "serve", out,
                    mode="smoke" if args.smoke else "full",
                    ledger_path=None if args.ledger == "none"
                    else args.ledger)
    print(f"# wrote {args.json}")
