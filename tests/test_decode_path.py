"""The decode fast path: split-K kernel, fp2fx8 KV cache, scanned loop.

Covers the three legs of the serving datapath:
  * split-K decode kernel vs the monolithic fused kernel — bitwise on a
    shared single-block shape (same blocking -> same arithmetic), error-
    enveloped on long masked multi-split shapes (the combine applies one
    extra Hyft rescale per split, like the sequence-parallel L2 layer);
  * the FP2FX-quantized int8 cache: round-trip error bound, update layout,
    fused-dequant kernel path;
  * ``generate``: scanned on-device loop == host loop token-for-token,
    dense and quantized, across attention modes and model families.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hyft import HYFT16, HYFT32
from repro.kernels import ops
from repro.models import attention as attn
from repro.models.attention import unfused_attention

F32 = jnp.float32


def _qkv(B, Hq, Hkv, Sk, D, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (B, Hq, 1, D), F32),
            jax.random.normal(ks[1], (B, Hkv, Sk, D), F32),
            jax.random.normal(ks[2], (B, Hkv, Sk, D), F32))


# --------------------------------------------------------------------------
# split-K decode kernel
# --------------------------------------------------------------------------


def test_splitk_bitwise_matches_monolithic_single_block():
    """One KV split == one monolithic kv block: identical blocking, so the
    split-K combine degenerates to alpha = hyft-exp(0) = 1.0 exactly and
    the outputs must agree bit for bit."""
    B, Hq, Hkv, Sk, D, valid = 2, 4, 2, 128, 32, 100
    q, k, v = _qkv(B, Hq, Hkv, Sk, D)
    mask = (jnp.arange(Sk)[None, :] < valid).astype(F32).repeat(B, 0)
    o_split = ops.hyft_decode_attention(q, k, v, HYFT32, kv_len_mask=mask,
                                        block_k=128)
    o_mono = ops.hyft_attention(q, k, v, HYFT32, causal=False,
                                kv_len_mask=mask, block_k=128)
    assert np.array_equal(np.asarray(o_split), np.asarray(o_mono))


@pytest.mark.parametrize("Sk,valid", [(2048, 1500), (2048, 2048), (512, 300)])
def test_splitk_long_masked_decode(Sk, valid):
    """Sk=2048 masked decode stays on the split-K kernel (no fallback) and
    lands inside the Hyft error envelope of both references."""
    B, Hq, Hkv, D = 1, 16, 8, 64
    q, k, v = _qkv(B, Hq, Hkv, Sk, D, seed=1)
    mask = (jnp.arange(Sk)[None, :] < valid).astype(F32).repeat(B, 0)
    o = ops.hyft_decode_attention(q, k, v, HYFT32, kv_len_mask=mask)
    assert o.shape == (B, Hq, 1, D)
    o_ref = unfused_attention(q, k, v, "hyft32", causal=False,
                              kv_len_mask=mask > 0)
    o_exact = unfused_attention(q, k, v, "exact", causal=False,
                                kv_len_mask=mask > 0)
    assert float(jnp.abs(o - o_ref).max()) < 0.06
    assert float(jnp.abs(o - o_exact).max()) < 0.10


def test_splitk_unaligned_and_tiny_kv():
    """Sk below one lane tile and non-multiples of the block are padded and
    the padding folded into the mask."""
    B, Hq, Hkv, Sk, D = 2, 4, 4, 16, 16
    q, k, v = _qkv(B, Hq, Hkv, Sk, D, seed=2)
    mask = (jnp.arange(Sk)[None, :] < 9).astype(F32).repeat(B, 0)
    o = ops.hyft_decode_attention(q, k, v, HYFT16, kv_len_mask=mask)
    o_ref = unfused_attention(q, k, v, "hyft16", causal=False,
                              kv_len_mask=mask > 0)
    assert float(jnp.abs(o.astype(F32) - o_ref.astype(F32)).max()) < 0.13
    o300 = ops.hyft_decode_attention(*_qkv(1, 8, 4, 300, 32, seed=3), HYFT32)
    assert o300.shape == (1, 8, 1, 32)
    assert bool(jnp.all(jnp.isfinite(o300)))


# --------------------------------------------------------------------------
# fp2fx8 KV cache
# --------------------------------------------------------------------------


def test_fp2fx8_roundtrip_error_bound():
    """|x - dequant(quant(x))| <= half an int8 ulp of the per-row scale."""
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 64, 32), F32) * 5
    raw, scale = attn.fp2fx8_quantize(x)
    assert raw.dtype == jnp.int8
    deq = attn.fp2fx8_dequantize(raw, scale)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    assert float((jnp.abs(deq - x) / amax).max()) <= 2.0 ** -7
    # the row max survives quantization without saturating
    assert int(jnp.abs(raw).max()) == 127


def test_fp2fx8_cache_update_layout():
    class Cfg:
        n_kv_heads, d_head = 2, 16
    cache = attn.cache_init(Cfg, 3, 8, "fp2fx8")
    assert attn.cache_is_quantized(cache)
    assert cache["k"].dtype == jnp.int8 and cache["k_scale"].shape == (3, 2, 8)
    k_new = jax.random.normal(jax.random.PRNGKey(1), (3, 2, 2, 16), F32)
    v_new = jax.random.normal(jax.random.PRNGKey(2), (3, 2, 2, 16), F32)
    cache = attn.cache_update(cache, k_new, v_new, 4)
    k_deq, v_deq = attn.cache_kv(cache)
    np.testing.assert_allclose(np.asarray(k_deq[:, :, 4:6]),
                               np.asarray(k_new), atol=0.05)
    np.testing.assert_allclose(np.asarray(v_deq[:, :, 4:6]),
                               np.asarray(v_new), atol=0.05)
    assert float(jnp.abs(k_deq[:, :, :4]).max()) == 0.0  # untouched slots


def test_splitk_fused_dequant_matches_dequant_then_dense():
    """The kernel's in-load dequant == dequantize-then-run on the same raws."""
    B, Hq, Hkv, Sk, D = 2, 8, 4, 256, 32
    q, k, v = _qkv(B, Hq, Hkv, Sk, D, seed=4)
    mask = (jnp.arange(Sk)[None, :] < 200).astype(F32).repeat(B, 0)
    kr, ks = attn.fp2fx8_quantize(k)
    vr, vs = attn.fp2fx8_quantize(v)
    o_fused = ops.hyft_decode_attention(q, kr, vr, HYFT32, kv_len_mask=mask,
                                        k_scale=ks, v_scale=vs)
    o_deq = ops.hyft_decode_attention(q, attn.fp2fx8_dequantize(kr, ks),
                                      attn.fp2fx8_dequantize(vr, vs), HYFT32,
                                      kv_len_mask=mask)
    np.testing.assert_allclose(np.asarray(o_fused), np.asarray(o_deq),
                               atol=1e-6, rtol=1e-6)
    # and quantization noise stays small vs the dense-cache kernel
    o_dense = ops.hyft_decode_attention(q, k, v, HYFT32, kv_len_mask=mask)
    assert float(jnp.abs(o_fused - o_dense).max()) < 0.08


def test_decode_attention_dispatch_quantized_kernel():
    """attn_mode=kernel + fp2fx8 cache -> split-K kernel on the raws; the
    result tracks the dequantized unfused reference."""
    from repro.configs.base import ModelConfig
    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=64,
                      n_heads=4, n_kv_heads=2, d_head=16, d_ff=128, vocab=32,
                      softmax_impl="hyft32", attn_mode="kernel")
    B, Sk = 2, 64
    q, k, v = _qkv(B, 4, 2, Sk, 16, seed=5)
    kr, ks = attn.fp2fx8_quantize(k)
    vr, vs = attn.fp2fx8_quantize(v)
    cache = {"k": kr, "v": vr, "k_scale": ks, "v_scale": vs}
    mask = (jnp.arange(Sk)[None, :] < 40).repeat(B, 0)
    o = attn.decode_attention(q, cache, cfg, kv_len_mask=mask)
    o_ref = unfused_attention(q, *attn.cache_kv(cache), "hyft32",
                              causal=False, kv_len_mask=mask)
    assert float(jnp.abs(o - o_ref).max()) < 0.06


# --------------------------------------------------------------------------
# scanned decode loop
# --------------------------------------------------------------------------


def _serve_setup(arch="qwen2-1.5b", **kw):
    from repro.configs import get_config, smoke_config
    from repro.models import build_model
    from repro.models.layers import unbox
    cfg = smoke_config(get_config(arch)).with_(
        softmax_impl="hyft16", vocab=64, **kw)
    model = build_model(cfg)
    params = unbox(model.init(jax.random.PRNGKey(0)))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0,
                                          cfg.vocab, jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (2, cfg.frontend_len, cfg.frontend_dim))
    return cfg, model, params, batch


@pytest.mark.parametrize("cache_dtype", ["float32", "fp2fx8"])
@pytest.mark.parametrize("attn_mode", [None, "kernel"])
def test_generate_scan_matches_host(cache_dtype, attn_mode):
    """The on-device lax.scan loop is token-for-token identical to the
    per-token host loop — dense and quantized cache, with and without the
    split-K kernel in the decode step."""
    from repro.configs.base import ServeConfig
    from repro.serve.engine import generate
    cfg, model, params, batch = _serve_setup()
    outs = {}
    for loop in ("host", "scan"):
        scfg = ServeConfig(max_len=16, cache_dtype=cache_dtype,
                           attn_mode=attn_mode, decode_loop=loop)
        outs[loop] = generate(model, params, batch, scfg, max_new=5)
    assert outs["scan"].shape == (2, 5)
    assert outs["scan"].dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(outs["host"]),
                                  np.asarray(outs["scan"]))
    assert bool(jnp.all((outs["scan"] >= 0) & (outs["scan"] < cfg.vocab)))


@pytest.mark.parametrize("arch", ["whisper-medium", "zamba2-7b"])
def test_generate_scan_other_families_quantized(arch):
    """Enc-dec and hybrid decode run the scanned loop over an fp2fx8 cache
    (SSM state / encoder memory stay float)."""
    from repro.configs.base import ServeConfig
    from repro.serve.engine import generate
    cfg, model, params, batch = _serve_setup(arch)
    outs = {}
    for loop in ("host", "scan"):
        scfg = ServeConfig(max_len=16, cache_dtype="fp2fx8", decode_loop=loop)
        outs[loop] = generate(model, params, batch, scfg, max_new=4)
    np.testing.assert_array_equal(np.asarray(outs["host"]),
                                  np.asarray(outs["scan"]))


def test_generate_sampled_scan_runs():
    """Temperature > 0 threads the PRNG through the scan carry."""
    from repro.configs.base import ServeConfig
    from repro.serve.engine import generate
    cfg, model, params, batch = _serve_setup()
    scfg = ServeConfig(max_len=16, cache_dtype="float32", temperature=0.8)
    out = generate(model, params, batch, scfg, max_new=6,
                   key=jax.random.PRNGKey(7))
    assert out.shape == (2, 6)
    assert bool(jnp.all((out >= 0) & (out < cfg.vocab)))


def test_serve_step_and_loop_are_cached():
    """Repeated generate calls reuse the compiled prefill/step/loop."""
    from repro.configs.base import ServeConfig
    from repro.serve import engine
    cfg, model, params, batch = _serve_setup()
    scfg = ServeConfig(max_len=16, cache_dtype="float32", decode_loop="scan")
    engine.generate(model, params, batch, scfg, max_new=3)
    n_loop, n_pre = len(engine._LOOP_CACHE), len(engine._PREFILL_CACHE)
    engine.generate(model, params, batch, scfg, max_new=3)
    assert len(engine._LOOP_CACHE) == n_loop
    assert len(engine._PREFILL_CACHE) == n_pre
    # a different horizon adds exactly one loop entry, reuses prefill
    engine.generate(model, params, batch, scfg, max_new=4)
    assert len(engine._LOOP_CACHE) == n_loop + 1
    assert len(engine._PREFILL_CACHE) == n_pre


def test_greedy_host_loop_skips_prng():
    """temperature == 0 must not consume PRNG entropy: the key never splits,
    so greedy decode is reproducible regardless of the key passed in."""
    from repro.configs.base import ServeConfig
    from repro.serve.engine import generate
    cfg, model, params, batch = _serve_setup()
    for loop in ("host", "scan"):
        scfg = ServeConfig(max_len=16, cache_dtype="float32", decode_loop=loop)
        o1 = generate(model, params, batch, scfg, max_new=4,
                      key=jax.random.PRNGKey(0))
        o2 = generate(model, params, batch, scfg, max_new=4,
                      key=jax.random.PRNGKey(123))
        np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))


# --------------------------------------------------------------------------
# satellite: _row_blocks clamping
# --------------------------------------------------------------------------


def test_row_blocks_clamps_to_rows():
    from repro.kernels.hyft_softmax import _row_blocks
    assert _row_blocks(4, 64, None) == 4          # fewer rows than the floor
    assert _row_blocks(4, 64, 128) == 4           # explicit block clamped too
    assert _row_blocks(10 ** 6, 64, None) == 512  # budget cap unchanged
    assert _row_blocks(10 ** 6, 10 ** 6, None) == 8


def test_small_row_softmax_kernel_matches_oracle():
    """rows < 8 used to force an 8-row block + padding; the clamped block
    must still agree with the pure-JAX oracle bit for bit."""
    from repro.core.hyft import hyft_softmax_fwd
    from repro.kernels.hyft_softmax import hyft_softmax_fwd_kernel
    z = jax.random.normal(jax.random.PRNGKey(0), (3, 64), F32) * 3
    out_k = hyft_softmax_fwd_kernel(z, HYFT32, interpret=True)
    out_o = hyft_softmax_fwd(z, HYFT32)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_o))
