"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hyft import HYFT16, HYFT32, HyftConfig
from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_hyft_attention
from repro.kernels.hyft_softmax import (hyft_softmax_bwd_kernel,
                                        hyft_softmax_fwd_kernel)

F32 = jnp.float32
KEY = jax.random.PRNGKey(42)


@pytest.mark.parametrize("cfg", [HYFT16, HYFT32], ids=["h16", "h32"])
@pytest.mark.parametrize("shape", [(8, 32), (37, 200), (3, 5, 64), (1, 1024)])
def test_fwd_kernel_bit_exact(cfg, shape):
    z = jax.random.normal(KEY, shape, F32) * 4
    a = hyft_softmax_fwd_kernel(z, cfg, interpret=True)
    b = ref.hyft_softmax_ref(z, cfg)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float16, jnp.bfloat16])
def test_fwd_kernel_input_dtypes(dtype):
    z = (jax.random.normal(KEY, (16, 64), F32) * 3).astype(dtype)
    a = hyft_softmax_fwd_kernel(z, HYFT16, interpret=True)
    b = ref.hyft_softmax_ref(z, HYFT16)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("step", [1, 2, 4])
def test_fwd_kernel_step(step):
    cfg = dataclasses.replace(HYFT32, step=step)
    z = jax.random.normal(KEY, (16, 64), F32) * 3
    a = hyft_softmax_fwd_kernel(z, cfg, interpret=True)
    b = ref.hyft_softmax_ref(z, cfg)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("cfg", [HYFT16, HYFT32], ids=["h16", "h32"])
def test_bwd_kernel_bit_exact(cfg):
    s = jax.nn.softmax(jax.random.normal(KEY, (24, 96), F32), -1)
    dy = jax.random.normal(jax.random.PRNGKey(1), (24, 96), F32)
    a = hyft_softmax_bwd_kernel(s, dy, cfg, interpret=True)
    b = ref.hyft_softmax_bwd_ref(s, dy, cfg)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_kernel_custom_vjp_matches_core():
    from repro.core.hyft import hyft_softmax as core_softmax
    z = jax.random.normal(KEY, (8, 32), F32)
    w = jax.random.normal(jax.random.PRNGKey(2), (32,))
    gk = jax.grad(lambda x: jnp.sum(ops.hyft_softmax(x, HYFT32) * w))(z)
    gc = jax.grad(lambda x: jnp.sum(core_softmax(x, HYFT32) * w))(z)
    np.testing.assert_array_equal(np.asarray(gk), np.asarray(gc))


class TestFlashAttention:
    def _qkv(self, B=1, Hq=4, Hkv=2, S=128, D=32):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (B, Hq, S, D), F32)
        k = jax.random.normal(ks[1], (B, Hkv, S, D), F32)
        v = jax.random.normal(ks[2], (B, Hkv, S, D), F32)
        return q, k, v

    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_blocked_oracle(self, causal):
        q, k, v = self._qkv()
        o = flash_hyft_attention(q, k, v, HYFT32, causal=causal,
                                 block_q=64, block_k=64, interpret=True)
        oref = ref.flash_hyft_attention_ref(q, k, v, HYFT32, causal=causal,
                                            block_q=64, block_k=64)
        # identical arithmetic; only fp32 matmul association differs
        np.testing.assert_allclose(np.asarray(o), np.asarray(oref),
                                   atol=2e-5, rtol=1e-5)

    def test_single_block_close_to_unfused(self):
        # one KV block => no online rescale; the remaining difference is the
        # division order: flash divides the PV accumulation (paper's DIV unit
        # after the pipeline), unfused divides each probability first --
        # bounded by one extra log-div Taylor application
        q, k, v = self._qkv(S=64)
        o = flash_hyft_attention(q, k, v, HYFT32, causal=True,
                                 block_q=64, block_k=64, interpret=True)
        ou = ref.attention_ref(q, k, v, HYFT32, causal=True)
        assert float(jnp.abs(o - ou).max()) < 0.25
        assert float(jnp.abs(o - ou).mean()) < 0.02

    def test_close_to_exact_attention(self):
        q, k, v = self._qkv(S=256)
        o = flash_hyft_attention(q, k, v, HYFT32, causal=True, interpret=True)
        oe = ref.attention_ref(q, k, v, None, causal=True)
        # bounded by the Hyft approximation chain, not by fusion
        assert float(jnp.abs(o - oe).max()) < 0.35
        assert float(jnp.abs(o - oe).mean()) < 0.02

    def test_gqa_groups(self):
        q, k, v = self._qkv(B=2, Hq=8, Hkv=2, S=64, D=16)
        o = flash_hyft_attention(q, k, v, HYFT16, causal=True,
                                 block_q=32, block_k=32, interpret=True)
        oref = ref.flash_hyft_attention_ref(q, k, v, HYFT16, causal=True,
                                            block_q=32, block_k=32)
        np.testing.assert_allclose(np.asarray(o), np.asarray(oref), atol=1e-4)

    def test_return_stats_shapes(self):
        q, k, v = self._qkv(S=64)
        o, m, l = flash_hyft_attention(q, k, v, HYFT32, causal=False,
                                       block_q=32, block_k=32,
                                       interpret=True, return_stats=True)
        assert m.shape == (1, 4, 64) and l.shape == (1, 4, 64)
        assert m.dtype == jnp.int32


class TestChunkedAttention:
    def test_chunked_matches_flash_math(self):
        from repro.models.attention import chunked_hyft_attention
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 4, 128, 32), F32)
        k = jax.random.normal(ks[1], (1, 2, 128, 32), F32)
        v = jax.random.normal(ks[2], (1, 2, 128, 32), F32)
        a = chunked_hyft_attention(q, k, v, HYFT32, True, 64, 0)
        b = flash_hyft_attention(q, k, v, HYFT32, causal=True, block_q=128,
                                 block_k=64, interpret=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=3e-5, rtol=1e-5)

    def test_chunked_backward_close_to_exact(self):
        from repro.models.attention import chunked_hyft_attention
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 2, 64, 16), F32)
        k = jax.random.normal(ks[1], (1, 2, 64, 16), F32)
        v = jax.random.normal(ks[2], (1, 2, 64, 16), F32)

        def f_hyft(q, k, v):
            return jnp.sum(chunked_hyft_attention(q, k, v, HYFT32, True, 32, 0))

        def f_exact(q, k, v):
            z = jnp.einsum("bhqd,bhkd->bhqk", q, k) * 16 ** -0.5
            mask = jnp.tril(jnp.ones((64, 64), bool))
            z = jnp.where(mask, z, -3e38)
            p = jax.nn.softmax(z, -1)
            return jnp.sum(jnp.einsum("bhqk,bhkd->bhqd", p, v))

        gh = jax.grad(f_hyft, argnums=(0, 1, 2))(q, k, v)
        ge = jax.grad(f_exact, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(gh, ge):
            assert float(jnp.abs(a - b).max()) < 0.35
