"""Paged KV subsystem: allocator, radix trie, paged kernel, paged serving.

The contract under test (DESIGN.md §10):
  * PagePool refcounts: alloc/incref/decref round-trip, zero frees, the
    null page is never handed out;
  * RadixTrie: insert/match page-granular prefixes, edge splits at page
    boundaries, LRU eviction frees trie-only pages and respects live refs,
    copy-on-write divergence never mutates a shared page;
  * fp2fx8 page quantize/dequantize round-trip error bounds;
  * ``flash_hyft_decode_paged`` is bitwise-equal to ``flash_hyft_decode``
    on sequentially laid out pages (dense and fp2fx8), and block-table
    permutations don't change it;
  * greedy paged serving matches the dense slot pool token-for-token
    (dense and fp2fx8 layouts), prefix-cache hits provably skip prefill
    (step counts) while producing identical tokens, and page exhaustion
    preempts + requeues without changing any output.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ServeConfig
from repro.serve.kvpool import NULL_PAGE, PagePool, RadixTrie

F32 = jnp.float32


# --------------------------------------------------------------------------
# PagePool
# --------------------------------------------------------------------------


def test_pool_alloc_free_refcounts():
    pool = PagePool(6)
    a = pool.alloc(4)
    assert a is not None and len(set(a)) == 4 and NULL_PAGE not in a
    assert pool.alloc(3) is None          # partial allocations never happen
    assert pool.free_pages == 2
    pool.incref(a[0])
    pool.decref(a[0])
    assert pool.pages_in_use == 4         # still held by the original ref
    for p in a:
        pool.decref(p)
    assert pool.free_pages == 6
    b = pool.alloc(6)
    assert b is not None and NULL_PAGE not in b


def test_pool_random_workload_conserves_pages():
    rng = np.random.default_rng(0)
    pool = PagePool(16)
    held = []
    for _ in range(300):
        if held and rng.random() < 0.5:
            pool.decref(held.pop(rng.integers(len(held))))
        else:
            got = pool.alloc(int(rng.integers(1, 4)))
            if got is not None:
                held.extend(got)
        assert pool.pages_in_use == len(held)
        assert pool.free_pages + pool.pages_in_use == 16
    for p in held:
        pool.decref(p)
    assert pool.free_pages == 16


# --------------------------------------------------------------------------
# RadixTrie
# --------------------------------------------------------------------------


def _trie(n_pages=32, ps=4):
    pool = PagePool(n_pages)
    return pool, RadixTrie(pool, ps)


def test_trie_insert_match_page_granular():
    pool, trie = _trie()
    toks = list(range(11))                 # 2 full pages + a partial tail
    pages = pool.alloc(3)
    assert trie.insert(toks, pages) == 2   # only full pages are adopted
    got, n = trie.match(toks)
    assert got == pages[:2] and n == 8
    # a shorter query matches only whole pages of itself
    got, n = trie.match(toks[:6])
    assert got == pages[:1] and n == 4
    got, n = trie.match([99] * 8)
    assert got == [] and n == 0


def test_trie_split_and_divergence_copy_on_write():
    """Two prompts sharing 2 pages then diverging: the edge splits at the
    page boundary, both suffixes coexist, and the shared pages keep their
    ids (nothing is copied — divergence lands in fresh pages)."""
    pool, trie = _trie()
    a = [1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12]       # 3 pages
    b = a[:8] + [99, 98, 97, 96]                       # shares 2 pages
    pa = pool.alloc(3)
    trie.insert(a, pa)
    got, n = trie.match(b)
    assert got == pa[:2] and n == 8                    # prefix reuse
    pb = pool.alloc(1)                                 # only the tail is new
    assert trie.insert(b, pa[:2] + pb) == 1            # adopts just the tail
    # both full prompts still resolve, through the split edge
    assert trie.match(a) == (pa, 12)
    assert trie.match(b) == (pa[:2] + pb, 12)
    assert pool.refs[pa[0]] == 2                       # alloc ref + trie ref


def test_trie_insert_keeps_existing_pages():
    """A duplicate insert with different page ids adopts nothing — the
    first writer's pages win and the duplicates stay private."""
    pool, trie = _trie()
    toks = list(range(8))
    p1, p2 = pool.alloc(2), pool.alloc(2)
    assert trie.insert(toks, p1) == 2
    assert trie.insert(toks, p2) == 0
    assert trie.match(toks) == (p1, 8)


def test_trie_evict_lru_frees_pages_and_respects_refs():
    pool, trie = _trie(n_pages=8)
    a, b = pool.alloc(2), pool.alloc(2)
    trie.insert([1, 2, 3, 4, 5, 6, 7, 8], a)
    trie.insert([9, 10, 11, 12, 13, 14, 15, 16], b)
    for p in a + b:
        pool.decref(p)                      # trie is now the only holder
    trie.match([9, 10, 11, 12])             # touch b: a becomes LRU
    pool.incref(a[0])
    pool.incref(a[1])                       # ...but a is pinned by a "slot"
    assert trie.evict(1) == 2               # so the b edge goes instead
    assert trie.match([9, 10, 11, 12]) == ([], 0)
    assert trie.match([1, 2, 3, 4]) == (a[:1], 4)
    pool.decref(a[0])
    pool.decref(a[1])
    assert trie.evict(2) == 2               # now a is evictable
    assert pool.free_pages == 8 and trie.n_pages() == 0


def test_trie_random_property_vs_reference():
    """Random inserts/matches against a brute-force reference: match must
    return the longest page-aligned prefix ever inserted, with the pages
    of the FIRST insert that covered each page."""
    rng = np.random.default_rng(3)
    ps = 2
    pool = PagePool(512)
    trie = RadixTrie(pool, ps)
    ref: dict = {}                           # page-path tuple -> page id
    for _ in range(60):
        n_tok = int(rng.integers(ps, 17))
        toks = rng.integers(0, 3, n_tok).tolist()   # small vocab: collisions
        pages = pool.alloc(-(-n_tok // ps))
        trie.insert(toks, pages)
        for j in range(n_tok // ps):
            ref.setdefault(tuple(toks[:(j + 1) * ps]), pages[j])
        q_len = int(rng.integers(0, 17))
        q = rng.integers(0, 3, q_len).tolist()
        got, n = trie.match(q)
        want = []
        for j in range(q_len // ps):
            key = tuple(q[:(j + 1) * ps])
            if key not in ref:
                break
            want.append(ref[key])
        # the trie may stop earlier at an unsplit partial edge, but what it
        # returns must be a prefix of the reference answer — and whenever it
        # returns less, the next reference page must sit mid-edge (the trie
        # never misses a node boundary)
        assert got == want[:len(got)], (q, got, want)
        assert n == len(got) * ps


def test_trie_match_exhaustive_after_inserts():
    """Full-prompt matches (the serving access pattern: query == an inserted
    prompt) are always complete, partial edges included."""
    rng = np.random.default_rng(4)
    ps = 2
    pool = PagePool(512)
    trie = RadixTrie(pool, ps)
    first: dict = {}
    prompts = []
    for _ in range(40):
        toks = rng.integers(0, 3, int(rng.integers(ps, 13))).tolist()
        pages = pool.alloc(len(toks) // ps)
        trie.insert(toks[:(len(toks) // ps) * ps], pages)
        prompts.append(toks)
        for j in range(len(toks) // ps):
            first.setdefault(tuple(toks[:(j + 1) * ps]), pages[j])
    for toks in prompts:
        got, n = trie.match(toks)
        want = [first[tuple(toks[:(j + 1) * ps])]
                for j in range(len(toks) // ps)]
        assert got == want and n == len(want) * ps


# --------------------------------------------------------------------------
# fp2fx8 page round-trip bounds
# --------------------------------------------------------------------------


def test_fp2fx8_roundtrip_error_bounds():
    """Quantize/dequantize of page content: the per-(head, position) amax
    scale bounds the round-trip error by scale/2 (round-to-nearest on a
    uniform int8 grid), rows round-trip exactly at 0, and the raws use the
    full int8 range."""
    from repro.models.attention import fp2fx8_dequantize, fp2fx8_quantize
    rng = np.random.default_rng(5)
    for scale_mag in (1e-3, 1.0, 37.5):
        x = jnp.asarray(rng.normal(0, scale_mag, (3, 4, 16, 32)), F32)
        raw, s = fp2fx8_quantize(x)
        back = fp2fx8_dequantize(raw, s)
        assert raw.dtype == jnp.int8
        err = np.abs(np.asarray(back - x))
        bound = np.asarray(s)[..., None] / 2 + 1e-12
        assert np.all(err <= bound), (err.max(), bound.min())
    z = jnp.zeros((2, 2, 4, 8), F32)
    raw, s = fp2fx8_quantize(z)
    assert np.all(np.asarray(fp2fx8_dequantize(raw, s)) == 0.0)


# --------------------------------------------------------------------------
# paged decode kernel: bitwise equality with the contiguous split-K kernel
# --------------------------------------------------------------------------


def _seq_pages(k, ps):
    """(B, Hkv, Sk, D) -> sequential page pool (B * Sk/ps, Hkv, ps, D)."""
    B, Hkv, Sk, D = k.shape
    nb = Sk // ps
    kp = k.transpose(0, 2, 1, 3).reshape(B, nb, ps, Hkv, D)
    return kp.transpose(0, 1, 3, 2, 4).reshape(B * nb, Hkv, ps, D)


@pytest.mark.parametrize("quantized", [False, True])
def test_paged_kernel_bitwise_vs_contiguous(quantized):
    from repro.core.registry import hyft_config_for
    from repro.kernels.flash_attention import (flash_hyft_decode,
                                               flash_hyft_decode_paged)
    from repro.models.attention import fp2fx8_quantize
    cfg = hyft_config_for("hyft16")
    B, Hq, Hkv, D, ps, nb = 2, 4, 2, 16, 16, 4
    Sk = ps * nb
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, Hq, 1, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Hkv, Sk, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Hkv, Sk, D))
    mask = (jnp.arange(Sk)[None, :]
            < jnp.array([37, 64])[:, None]).astype(F32)
    ks = vs = kps = vps = None
    if quantized:
        k, ks = fp2fx8_quantize(k)
        v, vs = fp2fx8_quantize(v)
        kps = _seq_pages(ks[..., None], ps)[..., 0]
        vps = _seq_pages(vs[..., None], ps)[..., 0]
    dense = flash_hyft_decode(q, k, v, cfg, block_k=ps, interpret=True,
                              kv_len_mask=mask, k_scale=ks, v_scale=vs)
    bt = jnp.arange(B * nb, dtype=jnp.int32).reshape(B, nb)
    paged = flash_hyft_decode_paged(
        q, _seq_pages(k, ps), _seq_pages(v, ps), bt, cfg, interpret=True,
        kv_len_mask=mask, k_scale=kps, v_scale=vps)
    assert paged.shape == (B, Hq, 1, D)
    assert jnp.all(dense == paged), "paged kernel != contiguous split-K"


def test_paged_kernel_invariant_to_page_placement():
    """Physically permuting the pool (with the block table following) must
    not change a bit — the kernel reads pages only through the table."""
    from repro.core.registry import hyft_config_for
    from repro.kernels.flash_attention import flash_hyft_decode_paged
    cfg = hyft_config_for("hyft16")
    B, Hq, Hkv, D, ps, nb = 2, 4, 2, 16, 8, 4
    key = jax.random.PRNGKey(7)
    q = jax.random.normal(key, (B, Hq, 1, D))
    kp = jax.random.normal(jax.random.fold_in(key, 1), (B * nb, Hkv, ps, D))
    vp = jax.random.normal(jax.random.fold_in(key, 2), (B * nb, Hkv, ps, D))
    bt = jnp.arange(B * nb, dtype=jnp.int32).reshape(B, nb)
    base = flash_hyft_decode_paged(q, kp, vp, bt, cfg, interpret=True)
    perm = jax.random.permutation(jax.random.fold_in(key, 3), B * nb)
    inv = jnp.argsort(perm)
    shuf = flash_hyft_decode_paged(q, kp[perm], vp[perm], inv[bt], cfg,
                                   interpret=True)
    assert jnp.all(base == shuf)


# --------------------------------------------------------------------------
# paged serving: parity, prefix-cache skip, preemption
# --------------------------------------------------------------------------


def _setup(vocab=64, **kw):
    from repro.configs import get_config, smoke_config
    from repro.models import build_model
    from repro.models.layers import unbox
    cfg = smoke_config(get_config("qwen2-1.5b")).with_(
        softmax_impl="hyft16", vocab=vocab, **kw)
    model = build_model(cfg)
    params = unbox(model.init(jax.random.PRNGKey(0)))
    return cfg, model, params


def _requests(cfg, n, rng, plen=(3, 9), max_new=(3, 9)):
    from repro.serve.scheduler import Request
    return [Request(
        rid=rid,
        tokens=rng.integers(0, cfg.vocab, int(rng.integers(*plen))).astype(
            np.int32),
        max_new=int(rng.integers(*max_new))) for rid in range(n)]


def _solo(model, params, req, scfg):
    from repro.serve.engine import generate
    out = generate(model, params, {"tokens": jnp.asarray(req.tokens)[None]},
                   scfg, max_new=req.max_new)
    return np.asarray(out)[0].tolist()


@pytest.mark.parametrize("cache_dtype", ["float32", "fp2fx8"])
def test_paged_matches_dense_slot_pool(cache_dtype):
    """Greedy paged serving == dense slot pool == solo generate, token for
    token, over both cache formats (page placement is invisible)."""
    from repro.serve.scheduler import SlotPoolEngine
    cfg, model, params = _setup()
    reqs = _requests(cfg, 5, np.random.default_rng(0))
    outs = {}
    for layout in ("dense", "paged"):
        scfg = ServeConfig(max_len=32, cache_dtype=cache_dtype,
                           scheduler="continuous", n_slots=3, decode_burst=4,
                           kv_layout=layout, page_size=4)
        eng = SlotPoolEngine(model, params, scfg)
        done = eng.run(reqs)
        outs[layout] = {rid: c.tokens for rid, c in done.items()}
        if layout == "paged":
            assert eng.stats["pages_peak"] > 0
            assert eng.pool.pages_in_use == 0      # every page returned
    assert outs["paged"] == outs["dense"]
    solo_cfg = ServeConfig(max_len=32, cache_dtype=cache_dtype)
    for r in reqs:
        assert outs["paged"][r.rid] == _solo(model, params, r, solo_cfg)


def test_prefix_cache_skips_prefill_and_matches():
    """Identical prompts served one after another: later admissions must
    hit the radix trie, push ONLY the un-cached suffix through the model
    (prefill_tokens step count), and still emit identical tokens."""
    from repro.serve.scheduler import SlotPoolEngine
    cfg, model, params = _setup()
    rng = np.random.default_rng(1)
    shared = rng.integers(0, cfg.vocab, 12).astype(np.int32)
    from repro.serve.scheduler import Request
    reqs = [Request(rid=i, tokens=np.concatenate(
                [shared, rng.integers(0, cfg.vocab, 3).astype(np.int32)]),
            max_new=5) for i in range(4)]
    scfg = ServeConfig(max_len=32, cache_dtype="float32",
                       scheduler="continuous", n_slots=1, decode_burst=4,
                       kv_layout="paged", page_size=4, prefix_cache=True)
    eng = SlotPoolEngine(model, params, scfg)
    done = eng.run(reqs)
    st = eng.stats
    assert st["prefix_hits"] == 3                 # every follower hits
    assert st["cached_tokens"] == 3 * 12          # the shared 12-token head
    # the FLOP-skip proof: model-visible prefill steps cover only the
    # un-cached tokens, not the full prompts
    assert st["prefill_tokens"] == st["prompt_tokens"] - st["cached_tokens"]
    assert st["prompt_tokens"] == sum(len(r.tokens) for r in reqs)
    solo_cfg = ServeConfig(max_len=32, cache_dtype="float32")
    for r in reqs:
        assert done[r.rid].tokens == _solo(model, params, r, solo_cfg), \
            f"rid={r.rid}"


def test_prefix_cache_shares_pages_between_live_slots():
    """Concurrent requests with the same prompt hold the SAME physical
    pages (refcount > trie+1) while both decode — and the shared pages are
    never written past admission (copy-on-write by page granularity)."""
    from repro.serve.scheduler import Request, SlotPoolEngine
    cfg, model, params = _setup()
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab, 9).astype(np.int32)
    scfg = ServeConfig(max_len=32, cache_dtype="float32",
                       scheduler="continuous", n_slots=2, decode_burst=2,
                       kv_layout="paged", page_size=4, prefix_cache=True)
    eng = SlotPoolEngine(model, params, scfg)
    # admit A alone first (populates the trie), then B mid-decode of A
    reqs = [Request(rid=0, tokens=prompt, max_new=12),
            Request(rid=1, tokens=prompt, max_new=12, arrival=0.05)]
    done = eng.run(reqs)
    assert eng.stats["prefix_hits"] == 1
    assert eng.stats["cached_tokens"] == 8        # 2 full pages of 4
    assert done[0].tokens == done[1].tokens       # same prompt, same greedy
    solo_cfg = ServeConfig(max_len=32, cache_dtype="float32")
    assert done[0].tokens == _solo(model, params, reqs[0], solo_cfg)


def test_page_exhaustion_preempts_and_requeues():
    """A pool too small for three full sequences must preempt the
    latest-arrival slot, requeue it through admission, and still produce
    the exact greedy outputs at full length."""
    from repro.serve.scheduler import SlotPoolEngine
    cfg, model, params = _setup()
    rng = np.random.default_rng(3)
    reqs = _requests(cfg, 3, rng, plen=(6, 7), max_new=(10, 11))
    scfg = ServeConfig(max_len=32, cache_dtype="float32",
                       scheduler="continuous", n_slots=3, decode_burst=4,
                       kv_layout="paged", page_size=4, n_pages=9)
    eng = SlotPoolEngine(model, params, scfg)
    done = eng.run(reqs)
    assert eng.stats["preemptions"] >= 1
    solo_cfg = ServeConfig(max_len=32, cache_dtype="float32")
    for r in reqs:
        assert len(done[r.rid].tokens) == r.max_new
        assert done[r.rid].tokens == _solo(model, params, r, solo_cfg)
    assert eng.pool.pages_in_use == 0


def test_eviction_cannot_steal_matched_prefix_pages():
    """A prefix match under page pressure must never hand the matched pages
    back out as the same request's fresh tail pages: the match is pinned
    before allocation-triggered eviction runs (and dropped entirely when
    the pinned prefix is the only reclaimable memory), so outputs stay
    correct even when the cached prefix itself must be evicted."""
    from collections import deque
    from repro.serve.scheduler import Request, SlotPoolEngine
    cfg, model, params = _setup()
    rng = np.random.default_rng(6)
    q_head = rng.integers(0, cfg.vocab, 8).astype(np.int32)
    reqs = [
        Request(rid=0, tokens=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                max_new=12),                  # long-runner pinning pool pages
        Request(rid=1, tokens=q_head, max_new=1),   # publishes q_head pages
        Request(rid=2, tokens=np.concatenate(
            [q_head, rng.integers(0, cfg.vocab, 8).astype(np.int32)]),
            max_new=4),                       # matches q_head under pressure
    ]
    scfg = ServeConfig(max_len=24, cache_dtype="float32",
                       scheduler="continuous", n_slots=2, decode_burst=4,
                       kv_layout="paged", page_size=4, n_pages=7,
                       prefix_cache=True)
    eng = SlotPoolEngine(model, params, scfg)
    # deterministic drive (run()'s admission depends on wall-clock arrivals):
    # grow rid 0's block table, publish rid 1's pages to the trie, then
    # admit rid 2 exactly when free pages < its un-matched demand
    eng.admit([reqs[0]], 0.0)
    eng._prefill_step(0.0)
    eng.burst(0.0)
    eng.burst(0.0)
    eng.admit([reqs[1]], 0.0)
    eng._prefill_step(0.0)
    assert eng.completions[1].tokens and int(eng.active.sum()) == 1
    assert eng.pool.free_pages < 2            # the pressure the bug needs
    eng.admit([reqs[2]], 0.0)
    # the buggy ordering hands the evicted prefix pages back as rid 2's
    # tail, aliasing one physical page at two virtual blocks — a slot's
    # block table must never contain duplicates
    for s in range(scfg.n_slots):
        pages = eng.slot_pages[s]
        assert len(pages) == len(set(pages)), f"slot {s} aliases {pages}"
    while eng.active.any() or eng.prefilling.any() or eng._queue:
        # drain, re-admitting requeues
        if eng._queue and any(rid is None for rid in eng.slot_rid):
            eng.admit([eng._queue.popleft()], 0.0)
        if eng.prefilling.any():
            eng._prefill_step(0.0)
        if eng.active.any():
            eng.burst(0.0)
    solo_cfg = ServeConfig(max_len=24, cache_dtype="float32")
    for r in reqs:
        assert eng.completions[r.rid].tokens == _solo(model, params, r,
                                                      solo_cfg), r.rid
    assert isinstance(eng._queue, deque) and not eng._queue


# --------------------------------------------------------------------------
# audit property tests: random admit/finish/preempt/evict/cancel sequences
# --------------------------------------------------------------------------


def _audit_sim(ops, n_pages=24, ps=2, vocab=3):
    """Drive PagePool + RadixTrie through a scheduler-shaped op sequence,
    auditing after EVERY op (DESIGN.md §13).  ``ops`` is a list of
    ``(kind, a, b)`` int triples; kind % 5 selects admit / finish /
    preempt / evict-storm / cancel — finish, preempt, and cancel all
    release a holder the same way (requeue is host-side bookkeeping), so
    the pool-level invariant they share is what's under test: refcounts
    recomputed from holders + trie edges always balance, and no page is
    ever double-freed or leaked."""
    pool = PagePool(n_pages)
    trie = RadixTrie(pool, ps)
    holders: list = []
    for kind, a, b in ops:
        k = kind % 5
        if k == 0:                        # admit: match, pin, alloc, publish
            n_tok = ps * (1 + a % 4) + b % ps
            toks = [(a * 7 + b * 3 + j) % vocab for j in range(n_tok)]
            matched, _ = trie.match(toks)
            # pin the match BEFORE any allocation-triggered eviction can
            # run — the ordering test_eviction_cannot_steal... guards
            for p in matched:
                pool.incref(p)
            nb_need = -(-n_tok // ps) - len(matched)
            tail = pool.alloc(nb_need) if nb_need > 0 else []
            if tail is None:
                trie.evict(nb_need)       # pressure path
                tail = pool.alloc(nb_need)
            if tail is None:              # admission deferred: unwind pins
                for p in matched:
                    pool.decref(p)
            else:
                pages = matched + tail
                holders.append(pages)
                nfull = n_tok // ps
                if nfull:
                    trie.insert(toks[:nfull * ps], pages[:nfull])
        elif k == 3:                      # eviction storm
            trie.evict(1 + a % 4)
        elif holders:                     # finish / preempt / cancel
            for p in holders.pop(a % len(holders)):
                pool.decref(p)
        pool.audit(holders, trie)
        trie.audit()
    for pages in holders:                 # drain: everything must come back
        for p in pages:
            pool.decref(p)
    pool.audit([], trie)
    trie.evict(1 << 30)
    assert pool.free_pages == n_pages


def test_audit_random_ops_seeded():
    """Seeded fallback for environments without hypothesis: 8 random
    40-op admit/finish/preempt/evict/cancel sequences, audits clean after
    every op and all pages recovered at drain."""
    rng = np.random.default_rng(9)
    for _ in range(8):
        ops = [tuple(int(x) for x in rng.integers(0, 64, 3))
               for _ in range(40)]
        _audit_sim(ops)


def test_audit_random_ops_hypothesis():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=40, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 63), st.integers(0, 63),
                              st.integers(0, 63)), max_size=60))
    def check(ops):
        _audit_sim(ops)

    check()


def test_paged_config_validation():
    from repro.serve.scheduler import SlotPoolEngine
    cfg, model, params = _setup()
    with pytest.raises(ValueError):   # pool can't hold one request
        SlotPoolEngine(model, params, ServeConfig(
            max_len=32, kv_layout="paged", page_size=4, n_pages=4))
    with pytest.raises(ValueError):   # prefix cache needs the paged layout
        SlotPoolEngine(model, params, ServeConfig(
            max_len=32, kv_layout="dense", prefix_cache=True))
    with pytest.raises(ValueError):
        SlotPoolEngine(model, params, ServeConfig(
            max_len=32, kv_layout="banana"))
