"""Cost accounting + regression ledger (DESIGN.md §16).

Covers: the cost-analysis join (known-matmul FLOPs match the analytic
count), CostBook record/observe gating and metric emission, the kernel
microbench rows, ledger append/compare round-trips, the tolerance policy
(seeded slowdown flagged, improvement never flagged, cross-host walls
skipped, exact mismatches always flagged), and the ``regress`` gate over a
fabricated artifact+ledger directory.  Everything runs against tmp dirs —
no dependence on the repo's committed BENCH files.
"""
import json
import os

import jax
import jax.numpy as jnp
import pytest

from repro.obs import Obs, ledger, profile

M, K, N = 64, 128, 32


def _matmul():
    f = jax.jit(lambda a, b: a @ b)
    a = jnp.zeros((M, K), jnp.float32)
    b = jnp.zeros((K, N), jnp.float32)
    return f, a, b


# ---------------------------------------------------------------------------
# cost-analysis join
# ---------------------------------------------------------------------------


def test_exec_cost_matmul_flops_match_analytic():
    f, a, b = _matmul()
    c = profile.exec_cost(f, a, b)
    assert c is not None
    assert c["flops"] == pytest.approx(2 * M * K * N)
    # operands + result all touched at least once
    assert c["bytes"] >= 4 * (M * K + K * N + M * N)


def test_join_cost_fields_and_roofline_fraction():
    cost = {"flops": 2e9, "bytes": 8e9, "transcendentals": 0.0}
    j = profile.join_cost(cost, wall_s=1.0)
    assert j["achieved_gflops"] == pytest.approx(2.0)
    assert j["achieved_gbps"] == pytest.approx(8.0)
    # 8 GB at 819 GB/s dominates 2 GFLOP at 197 TFLOP/s
    assert j["bound_dominant"] == "memory"
    assert j["roofline_fraction"] == pytest.approx(
        j["bound_us"] * 1e-6 / 1.0)
    assert 0 < j["roofline_fraction"] < 1


def test_costbook_record_observe_emits_metrics():
    obs = Obs.enabled()
    f, a, b = _matmul()
    c = obs.profile.record("mm", f, a, b)
    assert "mm" in obs.profile and c["trip_factor"] == 1.0
    j = obs.profile.observe("mm", 1e-3)
    assert j is not None
    g = obs.metrics.find("perf.roofline_fraction", executable="mm")
    assert g is not None and g.value == pytest.approx(j["roofline_fraction"])
    assert obs.metrics.find("perf.wall_s", executable="mm").count == 1
    s = obs.profile.summary()
    assert s["mm"]["calls"] == 1
    assert s["mm"]["wall_mean_us"] == pytest.approx(1000.0)


def test_costbook_disabled_is_noop_and_unknown_observe_none():
    book = profile.CostBook(enabled=False)
    f, a, b = _matmul()
    assert book.record("mm", f, a, b) is None
    assert "mm" not in book
    assert book.observe("mm", 1e-3) is None


def test_costbook_trip_factor_scales_cost():
    b1 = profile.CostBook(enabled=True)
    b4 = profile.CostBook(enabled=True)
    f, a, b = _matmul()
    c1 = b1.record("mm", f, a, b)
    c4 = b4.record("mm", f, a, b, trip_factor=4.0)
    assert c4["flops"] == pytest.approx(4 * c1["flops"])
    assert c4["bytes"] == pytest.approx(4 * c1["bytes"])


def test_microbench_smoke_one_kernel():
    from repro.analysis.pallas_check import default_registry
    entries = [e for e in default_registry() if e.name == "softmax_fwd"]
    rows = profile.microbench(entries=entries, iters=1)
    (row,) = rows
    assert row["kernel"] == "softmax_fwd" and row["format"] == "float32"
    assert row["us_per_call"] > 0
    assert "roofline_fraction" in row  # CPU backend provides cost analysis


def test_xla_profile_capture_window(tmp_path):
    out = str(tmp_path / "prof")
    with profile.xla_profile(out):
        jax.block_until_ready(jnp.ones((8, 8)) @ jnp.ones((8, 8)))
    files = [os.path.join(d, f) for d, _, fs in os.walk(out) for f in fs]
    assert files, "capture window wrote nothing"
    with profile.xla_profile(None):
        pass  # falsy outdir: no-op


# ---------------------------------------------------------------------------
# ledger
# ---------------------------------------------------------------------------


def _prov(ts, host="hostA", mode="full", sha="aaaa111"):
    return {"backend": "cpu", "device_kind": "cpu", "interpret": True,
            "jax_version": "0.0", "git_sha": sha, "host": host, "ts": ts,
            "mode": mode}


KERNEL_RESULTS = {"kernels": [
    {"kernel": "softmax_fwd", "us_per_call": 100.0},
    {"kernel": "flash_fwd", "us_per_call": 50.0}]}


def test_provenance_has_all_keys():
    p = ledger.provenance("smoke")
    assert set(ledger.PROVENANCE_KEYS) <= set(p)
    assert p["mode"] == "smoke" and p["backend"] == jax.default_backend()


def test_ledger_append_load_roundtrip(tmp_path):
    path = str(tmp_path / "ledger.jsonl")
    row = ledger.append(path, "kernels", KERNEL_RESULTS, prov=_prov(1.0))
    rows = ledger.load(path)
    assert rows == [row]
    assert rows[0]["metrics"]["kernels.count"] == 2.0
    assert rows[0]["metrics"]["kernels.softmax_fwd.us_per_call"] == 100.0
    ledger.append(path, "kernels", KERNEL_RESULTS, prov=_prov(2.0))
    assert len(ledger.load(path)) == 2  # append-only


def test_baseline_prefers_strictly_older_then_self():
    rows = [{"bench": "kernels", "provenance": _prov(1.0, sha="old1"),
             "metrics": {}},
            {"bench": "kernels", "provenance": _prov(2.0, sha="old2"),
             "metrics": {}},
            {"bench": "kernels", "provenance": _prov(3.0, sha="self"),
             "metrics": {}}]
    b = ledger.baseline_for(rows, "kernels", _prov(3.0, sha="self"))
    assert b["provenance"]["git_sha"] == "old2"  # newest strictly older
    b = ledger.baseline_for(rows[2:], "kernels", _prov(3.0, sha="self"))
    assert b["provenance"]["git_sha"] == "self"  # self-row fallback
    # a smoke-mode run never matches full-mode baselines
    assert ledger.baseline_for(rows, "kernels",
                               _prov(9.0, mode="smoke")) is None


def test_compare_flags_seeded_slowdown_not_improvement():
    base = {"provenance": _prov(1.0),
            "metrics": {"kernels.softmax_fwd.us_per_call": 100.0,
                        "kernels.flash_fwd.us_per_call": 50.0,
                        "kernels.count": 2.0}}
    slow = ledger.extract("kernels", {"kernels": [
        {"kernel": "softmax_fwd", "us_per_call": 400.0},   # 3x worse
        {"kernel": "flash_fwd", "us_per_call": 10.0}]})    # improvement
    fs = ledger.compare(base, slow, _prov(2.0), bench="kernels")
    assert len(fs) == 1 and "softmax_fwd" in fs[0].where
    assert fs[0].rule == "regress.wall"


def test_compare_skips_wall_across_hosts_but_not_exact():
    base = {"provenance": _prov(1.0, host="hostA"),
            "metrics": {"kernels.softmax_fwd.us_per_call": 100.0,
                        "kernels.count": 2.0}}
    cur = ledger.extract("kernels", {"kernels": [
        {"kernel": "softmax_fwd", "us_per_call": 9999.0}]})
    fs = ledger.compare(base, cur, _prov(2.0, host="hostB"))
    # the wall slowdown is skipped (different host) but the kernel-count
    # change is exact and always compared
    assert [f.rule for f in fs] == ["regress.exact"]
    assert "kernels.count" in fs[0].where


def test_compare_ratio_within_tolerance_passes():
    base = {"provenance": _prov(1.0),
            "metrics": {"spec.acceptance_rate": 0.8}}
    m = [ledger.Metric("spec.acceptance_rate", 0.6, "ratio", "higher", 0.3)]
    assert ledger.compare(base, m, _prov(2.0)) == []   # -25% < 30% tol
    m = [ledger.Metric("spec.acceptance_rate", 0.4, "ratio", "higher", 0.3)]
    assert len(ledger.compare(base, m, _prov(2.0))) == 1


def _write_artifact(root, results, prov):
    results = dict(results)
    results["provenance"] = prov
    with open(os.path.join(root, "BENCH_kernels.json"), "w") as f:
        json.dump(results, f)


def test_regress_clean_and_seeded_slowdown(tmp_path):
    root = str(tmp_path)
    lpath = os.path.join(root, ledger.LEDGER)
    prov = _prov(2000.0)
    _write_artifact(root, KERNEL_RESULTS, prov)
    ledger.append(lpath, "kernels", KERNEL_RESULTS, prov=prov)
    lines = []
    assert ledger.regress(root, report=lines.append) == []  # self-row clean
    assert any("kernels" in ln for ln in lines)
    # seed a FASTER older baseline: the committed artifact now reads as a
    # slowdown the gate must flag
    fast = {"kernels": [{"kernel": "softmax_fwd", "us_per_call": 10.0},
                        {"kernel": "flash_fwd", "us_per_call": 5.0}]}
    ledger.append(lpath, "kernels", fast, prov=_prov(1000.0, sha="fastold"))
    fs = ledger.regress(root, report=lambda *_: None)
    assert fs and all(f.rule == "regress.wall" for f in fs)
    assert {f.where for f in fs} == {
        "kernels:kernels.softmax_fwd.us_per_call",
        "kernels:kernels.flash_fwd.us_per_call"}


def test_regress_missing_provenance_is_a_finding(tmp_path):
    root = str(tmp_path)
    with open(os.path.join(root, "BENCH_kernels.json"), "w") as f:
        json.dump(KERNEL_RESULTS, f)  # no provenance stamp
    fs = ledger.regress(root, report=lambda *_: None)
    assert len(fs) == 1 and fs[0].rule == "regress.no-provenance"


def test_finalize_stamps_provenance_and_appends(tmp_path):
    path = str(tmp_path / "BENCH_kernels.json")
    res = ledger.finalize(path, "kernels", KERNEL_RESULTS, mode="smoke")
    assert set(ledger.PROVENANCE_KEYS) <= set(res["provenance"])
    assert res["provenance"]["mode"] == "smoke"
    with open(path) as f:
        assert json.load(f)["provenance"] == res["provenance"]
    rows = ledger.load(str(tmp_path / ledger.LEDGER))
    assert len(rows) == 1 and rows[0]["bench"] == "kernels"
    # and the freshly finalized state passes its own regress gate
    assert ledger.regress(str(tmp_path), report=lambda *_: None) == []


# ---------------------------------------------------------------------------
# metrics satellites: atomic snapshot export
# ---------------------------------------------------------------------------


def test_write_jsonl_atomic_and_linewise(tmp_path):
    from repro.obs.metrics import Registry
    reg = Registry()
    reg.counter("c").inc()
    path = str(tmp_path / "m.jsonl")
    reg.write_jsonl(path)
    reg.counter("c").inc()
    reg.write_jsonl(path)
    with open(path) as f:
        lines = [json.loads(ln) for ln in f if ln.strip()]
    assert len(lines) == 2  # one line per snapshot, all parseable
    assert lines[1]["metrics"][0]["value"] == 2
    assert not [f for f in os.listdir(tmp_path) if ".tmp" in f]
