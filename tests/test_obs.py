"""Observability layer (DESIGN.md §15): tracer, metrics, numerics, and the
scheduler integration.

Under test:
  * tracer — event shapes (X/i/C), disabled no-op, Perfetto-loadable
    output (NaN args stringified), ``compile_watch`` counting + logger
    restore;
  * metrics — histogram sketch percentiles within the documented ~2.5%
    relative error, exact count/sum, one-kind-per-name binding, JSONL
    snapshot export;
  * numerics — device-side ``logit_stats``/``format_stats`` values on
    known inputs, monitor folding (non-finite kept as ``last`` but not
    folded), quarantine annotation;
  * scheduler — the legacy ``stats`` dict is a faithful view over the
    registry, metric totals reconcile with the Completion records, a
    traced serve covers the span taxonomy, ``Completion.ttft`` is None
    when nothing was emitted;
  * StragglerMonitor — warm-up folding, EMA convergence, outlier
    flagged-not-folded, and the warm-estimate handoff to the device-side
    deadline TTL (``_observe_burst`` -> ``_ttl_vector``);
  * lint — ``obs.untimed-hot-path`` fires on unspanned hot loops and
    respects span scopes and waivers.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ServeConfig

# --------------------------------------------------------------------------
# tracer
# --------------------------------------------------------------------------


def test_tracer_event_shapes(tmp_path):
    from repro.obs.trace import Tracer
    clock = iter(np.arange(0.0, 10.0, 0.5))
    tr = Tracer(enabled=True, clock=lambda: next(clock))
    with tr.span("admit", n=3):
        tr.instant("preempt", rid=7)
    tr.counter("queue", depth=2)
    assert [e["ph"] for e in tr.events] == ["i", "X", "C"]
    span = next(e for e in tr.events if e["ph"] == "X")
    assert span["name"] == "admit" and span["args"] == {"n": 3}
    assert span["dur"] == pytest.approx(1.0 * 1e6)  # two clock ticks
    assert tr.span_kinds() == {"admit", "preempt", "queue"}
    p = tmp_path / "t.json"
    tr.write(str(p))
    d = json.loads(p.read_text())
    assert set(d) == {"traceEvents", "displayTimeUnit"}
    assert len(d["traceEvents"]) == 3


def test_tracer_disabled_is_noop():
    from repro.obs.trace import NULL_TRACER
    with NULL_TRACER.span("x", a=1):
        NULL_TRACER.instant("y")
    NULL_TRACER.counter("z", v=1)
    NULL_TRACER.compile_span("f", 0.1, "xla")
    assert NULL_TRACER.events == []


def test_tracer_write_sanitizes_nonfinite(tmp_path):
    """Quarantine instants carry poisoned stats; NaN/Inf are not valid
    JSON and must be stringified so Perfetto still loads the file."""
    from repro.obs.trace import Tracer
    tr = Tracer(enabled=True)
    tr.instant("quarantine", z_max=float("nan"), z_min=float("-inf"),
               nested={"a": [float("inf"), 1.0]})
    p = tmp_path / "t.json"
    tr.write(str(p))
    raw = p.read_text()
    assert "NaN" not in raw and "Infinity" not in raw
    args = json.loads(raw)["traceEvents"][0]["args"]
    assert args["z_max"] == "nan" and args["z_min"] == "-inf"
    assert args["nested"]["a"] == ["inf", 1.0]


def test_compile_watch_counts_and_restores():
    import logging
    from repro.obs.trace import Tracer, compile_watch
    logger = logging.getLogger("jax")
    before = (logger.level, logger.propagate, list(logger.handlers))
    tr = Tracer(enabled=True)
    with compile_watch(tr) as w:
        jax.jit(lambda x: x * 2 + 1)(jnp.ones(3)).block_until_ready()
    assert any("<lambda>" in c for c in w.listener.compiles)
    assert "compile" in tr.span_kinds()
    after = (logger.level, logger.propagate, list(logger.handlers))
    assert before == after
    # enabled=False is a no-op shell
    with compile_watch(enabled=False) as w2:
        jax.jit(lambda x: x - 5)(jnp.ones(4)).block_until_ready()
    assert w2.listener.compiles == []


def test_retrace_guard_still_guards():
    """The PR 8 RetraceGuard API survives its rebase onto compile_watch."""
    from repro.analysis.retrace import RetraceError, RetraceGuard
    with pytest.raises(RetraceError):
        with RetraceGuard():
            jax.jit(lambda x: x * 7)(jnp.ones(5)).block_until_ready()
    with RetraceGuard(max_compiles=16) as g:
        jax.jit(lambda x: x * 11)(jnp.ones(6)).block_until_ready()
    assert g.compiles  # inspectable after exit


# --------------------------------------------------------------------------
# metrics
# --------------------------------------------------------------------------


def test_histogram_percentiles_within_sketch_error():
    from repro.obs.metrics import Histogram
    rng = np.random.default_rng(0)
    vals = rng.lognormal(mean=-3.0, sigma=1.5, size=5000)
    h = Histogram()
    for v in vals:
        h.observe(float(v))
    assert h.count == len(vals)
    assert h.total == pytest.approx(vals.sum())
    assert h.vmin == vals.min() and h.vmax == vals.max()
    for q in (50, 90, 99):
        exact = np.percentile(vals, q)
        assert h.percentile(q) == pytest.approx(exact, rel=0.05), q
    s = h.summary()
    assert s["count"] == len(vals) and s["mean"] == pytest.approx(vals.mean())


def test_histogram_empty_and_underflow():
    from repro.obs.metrics import Histogram
    h = Histogram()
    # empty histogram: percentiles are None (0.0 would read as a real —
    # excellent — latency downstream), count/sum stay numeric
    assert h.percentile(50) is None
    s = h.summary()
    assert s["count"] == 0 and s["min"] == 0.0 and s["p99"] is None
    h.observe(0.0)  # underflow bucket
    h.observe(5.0)
    assert h.count == 2 and h.percentile(0) == 0.0


def test_registry_kind_binding_and_find():
    from repro.obs.metrics import Registry
    r = Registry()
    c = r.counter("serve.tokens", scheduler="continuous")
    c.inc(5)
    assert r.counter("serve.tokens", scheduler="continuous") is c
    assert r.counter("serve.tokens", scheduler="spec").value == 0
    with pytest.raises(TypeError):
        r.gauge("serve.tokens")
    assert r.find("serve.tokens", scheduler="continuous").value == 5
    assert r.find("serve.tokens", scheduler="lockstep") is None


def test_registry_snapshot_jsonl(tmp_path):
    from repro.obs.metrics import Registry
    r = Registry()
    r.counter("a").inc(3)
    r.gauge("b").set(1.5)
    r.histogram("c").observe(0.25)
    p = tmp_path / "m.jsonl"
    r.write_jsonl(str(p))
    r.counter("a").inc(1)
    r.write_jsonl(str(p))
    lines = [json.loads(line) for line in p.read_text().splitlines()]
    assert len(lines) == 2
    byname = {m["name"]: m for m in lines[-1]["metrics"]}
    assert byname["a"]["value"] == 4 and byname["a"]["kind"] == "counter"
    assert byname["b"]["value"] == 1.5
    assert byname["c"]["count"] == 1 and byname["c"]["sum"] == 0.25
    assert "a 4" in r.report()


# --------------------------------------------------------------------------
# numerics
# --------------------------------------------------------------------------


def test_logit_stats_known_values():
    from repro.obs import numerics as obs_numerics
    logits = jnp.asarray([[2.0, -6.0, 4.0], [100.0, 0.0, -100.0]], jnp.float32)
    active = jnp.asarray([True, False])
    z = np.asarray(obs_numerics.logit_stats(logits, active))
    # only the active row counts: max 4, min -6, post-sub min -10
    assert z.tolist() == [4.0, -6.0, -10.0]
    r = obs_numerics.reduce_logit_stats(jnp.stack([z, z * 2]))
    assert r["z_max"] == 8.0 and r["z_min"] == -12.0
    assert r["zsub_min"] == -20.0


def test_format_stats_fp2fx8_cache():
    from repro.obs import numerics as obs_numerics
    raws = jnp.asarray([[127, -127, 3], [0, 1, 2]], jnp.int8)
    cache = {"k": raws, "k_scale": jnp.asarray([0.5 * 2**-7, 0.25 * 2**-7],
                                               jnp.float32),
             "written": jnp.asarray([1.0, 1.0], jnp.float32)}
    s = {k: np.asarray(v) for k, v in obs_numerics.format_stats(cache).items()}
    assert int(s["kv_saturated"]) == 2
    assert obs_numerics.format_stats({"k": jnp.zeros((2, 2), jnp.float32)}) \
        == {}


def test_numerics_monitor_folding_and_quarantine():
    from repro.obs.numerics import NumericsMonitor
    m = NumericsMonitor()
    m.update({"z_max": jnp.float32(3.0), "z_min": jnp.float32(-2.0),
              "zsub_min": jnp.float32(-5.0)})
    m.update({"z_max": jnp.float32(float("nan")),
              "z_min": jnp.float32(float("nan")),
              "zsub_min": jnp.float32(float("nan"))})
    s = m.summary()
    # NaN burst is kept as `last` (for quarantine annotation) but the
    # running range stays finite
    assert s["z_max"] == 3.0 and s["zsub_min"] == -5.0
    ev = m.record_quarantine(9, "burst")
    assert ev["rid"] == 9 and ev["where"] == "burst"
    assert np.isnan(ev["z_max"])
    assert m.summary()["quarantine_events"] == [ev]


# --------------------------------------------------------------------------
# Completion.ttft
# --------------------------------------------------------------------------


def test_ttft_none_when_no_tokens_emitted():
    from repro.serve.scheduler import Completion
    c = Completion(rid=0, tokens=[], prompt_len=4, finished_at=2.0,
                   arrival=1.0, cancelled=True)
    assert c.ttft is None
    assert c.latency == 1.0
    c2 = Completion(rid=1, tokens=[5], prompt_len=4, finished_at=2.0,
                    arrival=1.0, token_times=[1.25])
    assert c2.ttft == pytest.approx(0.25)


# --------------------------------------------------------------------------
# StragglerMonitor + deadline-TTL handoff
# --------------------------------------------------------------------------


def test_straggler_warmup_folds_without_flagging():
    from repro.distributed.fault_tolerance import StragglerMonitor
    m = StragglerMonitor()
    assert not any(m.observe(10.0) for _ in range(m.warm))
    assert m.flagged == 0 and m.ema > 0


def test_straggler_ema_converges():
    from repro.distributed.fault_tolerance import StragglerMonitor
    m = StragglerMonitor()
    for _ in range(100):
        m.observe(0.5)
    assert m.ema == pytest.approx(0.5, rel=1e-3)
    assert m.flagged == 0


def test_straggler_outlier_flagged_not_folded():
    from repro.distributed.fault_tolerance import StragglerMonitor
    m = StragglerMonitor()
    for _ in range(20):
        m.observe(0.1)
    ema_before = m.ema
    assert m.observe(1.0)  # 10x the EMA, threshold is 3x
    assert m.flagged == 1
    assert m.ema == ema_before  # outliers don't pollute the estimate
    assert not m.observe(0.1)   # normal observations keep folding


def test_straggler_warm_handoff_to_deadline_ttl():
    """``_observe_burst`` feeds the EMA into ``_step_ema``; once warm,
    ``_ttl_vector`` converts wall-clock deadlines into per-slot device
    step budgets (clipped to >= 1), and no-deadline slots stay TTL_NONE."""
    from repro.distributed.fault_tolerance import StragglerMonitor
    from repro.obs.metrics import Histogram
    from repro.serve.scheduler import Request, SlotPoolEngine, TTL_NONE

    eng = SlotPoolEngine.__new__(SlotPoolEngine)  # no model build needed
    eng.straggler = StragglerMonitor()
    eng._step_ema = 0.0
    eng.scfg = ServeConfig(n_slots=3)
    eng._hists = {"burst_wall_s": Histogram()}
    eng._count = lambda *a, **k: None

    # cold: no estimate yet -> every slot TTL_NONE
    eng.slot_rid = [0, 1, None]
    eng.active = np.array([True, True, False])
    eng.requests = {0: Request(rid=0, tokens=np.zeros(2, np.int32),
                               max_new=4, deadline=10.0),
                    1: Request(rid=1, tokens=np.zeros(2, np.int32),
                               max_new=4)}
    assert (eng._ttl_vector(now=0.0) == TTL_NONE).all()

    for _ in range(10):  # warm the estimate: 0.4 s bursts of 4 steps
        eng._observe_burst(0.4, steps=4)
    assert eng._step_ema == pytest.approx(0.1, rel=1e-3)

    ttl = eng._ttl_vector(now=9.5)
    assert ttl[0] == 5          # 0.5 s left / 0.1 s per step
    assert ttl[1] == TTL_NONE   # no deadline
    assert ttl[2] == TTL_NONE   # empty slot
    assert eng._ttl_vector(now=99.0)[0] == 1  # already late: clipped, >= 1


# --------------------------------------------------------------------------
# scheduler integration
# --------------------------------------------------------------------------


def _setup(vocab=64, **kw):
    from repro.configs import get_config, smoke_config
    from repro.models import build_model
    from repro.models.layers import unbox
    cfg = smoke_config(get_config("qwen2-1.5b")).with_(
        softmax_impl="hyft16", vocab=vocab, **kw)
    model = build_model(cfg)
    params = unbox(model.init(jax.random.PRNGKey(0)))
    return cfg, model, params


def _requests(cfg, n, rng, plen=(3, 9), max_new=(3, 9)):
    from repro.serve.scheduler import Request
    return [Request(
        rid=rid,
        tokens=rng.integers(0, cfg.vocab, int(rng.integers(*plen))).astype(
            np.int32),
        max_new=int(rng.integers(*max_new))) for rid in range(n)]


@pytest.mark.slow
def test_traced_serve_stats_view_and_reconciliation(tmp_path):
    """One traced serve: the legacy stats dict mirrors the registry, the
    token counter and TTFT/TBT histograms reconcile exactly with the
    Completion records, the trace file covers the core span kinds, and
    the metrics JSONL export wrote parseable snapshots."""
    from repro.obs import Obs
    from repro.serve.scheduler import SlotPoolEngine
    cfg, model, params = _setup()
    reqs = _requests(cfg, 5, np.random.default_rng(0))
    scfg = ServeConfig(max_len=32, cache_dtype="float32",
                       scheduler="continuous", n_slots=3, decode_burst=4)
    mpath = tmp_path / "m.jsonl"
    obs = Obs.enabled(metrics_path=str(mpath))
    eng = SlotPoolEngine(model, params, scfg, obs=obs)
    eng.prewarm(max(len(r.tokens) for r in reqs))
    done = eng.run(reqs)

    st = eng.stats
    lab = dict(scheduler="continuous", family=cfg.family)
    assert st["tokens_emitted"] == \
        obs.metrics.find("serve.tokens_emitted", **lab).value
    assert st["peak_active"] == \
        obs.metrics.find("serve.peak_active", **lab).value
    assert st["tokens_emitted"] == sum(len(c.tokens) for c in done.values())

    ttfts = [c.ttft for c in done.values() if c.ttft is not None]
    h = obs.metrics.find("serve.ttft_s", **lab)
    assert h.count == len(ttfts)
    assert h.total == pytest.approx(sum(ttfts))
    gaps = [g for c in done.values() for g in np.diff(c.token_times)]
    hb = obs.metrics.find("serve.tbt_s", **lab)
    assert hb.count == len(gaps)
    assert hb.total == pytest.approx(sum(gaps))

    kinds = obs.tracer.span_kinds()
    assert {"prewarm", "admit", "prefill_chunk", "decode_burst",
            "compile"} <= kinds, kinds
    tpath = tmp_path / "t.json"
    obs.tracer.write(str(tpath))
    evs = json.loads(tpath.read_text())["traceEvents"]
    assert all(e["ph"] in ("X", "i", "C") for e in evs)
    lines = [json.loads(line) for line in mpath.read_text().splitlines()]
    assert lines and all("metrics" in d for d in lines)


@pytest.mark.slow
def test_stats_view_default_obs_matches_legacy_shape():
    """Without an injected Obs the engine still exposes the full legacy
    stats dict (the PR 3-8 keys, zero-initialized, ints)."""
    from repro.serve.scheduler import SlotPoolEngine
    cfg, model, params = _setup()
    scfg = ServeConfig(max_len=32, cache_dtype="float32",
                       scheduler="continuous", n_slots=2, decode_burst=4)
    eng = SlotPoolEngine(model, params, scfg)
    st = eng.stats
    for k in ("admitted", "bursts", "prefills", "tokens_emitted",
              "quarantines", "fp32_retries", "stragglers", "audits",
              "peak_active", "pages_peak"):
        assert st[k] == 0, k
    done = eng.run(_requests(cfg, 3, np.random.default_rng(1)))
    assert eng.stats["tokens_emitted"] == \
        sum(len(c.tokens) for c in done.values())


@pytest.mark.slow
def test_telemetry_quarantine_annotated_under_nan_poison():
    """fp2fx8 + telemetry + NaN-poison chaos: the numeric-health ladder
    fires and every quarantine event carries the device-side stats that
    triggered it (the §13 'explainable quarantine' acceptance)."""
    from repro.obs import Obs
    from repro.serve.chaos import ChaosMonkey, FaultPlan
    from repro.serve.scheduler import SlotPoolEngine
    cfg, model, params = _setup()
    reqs = _requests(cfg, 4, np.random.default_rng(2), plen=(4, 8),
                     max_new=(6, 10))
    scfg = ServeConfig(max_len=24, cache_dtype="fp2fx8",
                       scheduler="continuous", n_slots=2, decode_burst=4,
                       telemetry=True)
    monkey = ChaosMonkey(FaultPlan(seed=5, nan_kv_rate=0.5, max_faults=3))
    eng = SlotPoolEngine(model, params, scfg, chaos=monkey, obs=Obs())
    eng.prewarm(max(len(r.tokens) for r in reqs))
    done = eng.run(reqs)
    assert set(done) == {r.rid for r in reqs}

    s = eng.obs.numerics.summary()
    assert s["bursts"] > 0 and np.isfinite(s["z_max"])
    assert s["kv_int8_total"] > 0 and s["kv_scale_hist"]
    assert s["converts"] > 0
    assert eng.stats["quarantines"] > 0
    for ev in s["quarantine_events"]:
        assert {"rid", "where", "z_max", "z_min", "zsub_min",
                "kv_saturated"} <= set(ev)
    # the poison that fired the quarantine is visible in the annotation
    assert any(not np.isfinite(ev["z_max"]) or not np.isfinite(ev["zsub_min"])
               for ev in s["quarantine_events"])


@pytest.mark.slow
def test_telemetry_does_not_change_outputs():
    """telemetry=True only APPENDS stats to the burst outputs — greedy
    tokens are unchanged."""
    from repro.serve.scheduler import SlotPoolEngine
    cfg, model, params = _setup()
    reqs = _requests(cfg, 4, np.random.default_rng(3))
    outs = {}
    for tel in (False, True):
        scfg = ServeConfig(max_len=32, cache_dtype="float32",
                           scheduler="continuous", n_slots=2,
                           decode_burst=4, telemetry=tel)
        eng = SlotPoolEngine(model, params, scfg)
        done = eng.run([r for r in reqs])
        outs[tel] = {rid: c.tokens for rid, c in done.items()}
    assert outs[False] == outs[True]


# --------------------------------------------------------------------------
# lint: obs.untimed-hot-path
# --------------------------------------------------------------------------

_LOOP = """
import jax
step = jax.jit(lambda x: x + 1)
for i in range(10):
    y = step(i)
"""

_LOOP_SPANNED = """
import jax
step = jax.jit(lambda x: x + 1)
with tracer.span("decode"):
    for i in range(10):
        y = step(i)
"""

_LOOP_INNER_SPAN = """
import jax
step = jax.jit(lambda x: x + 1)
for i in range(10):
    with tracer.span("step"):
        y = step(i)
"""

_LOOP_WAIVED = """
import jax
step = jax.jit(lambda x: x + 1)
for i in range(10):
    y = step(i)  # lint: allow(obs.untimed-hot-path)
"""

_BUILDER_ATTR = """
class Eng:
    def __init__(self):
        self._burst = build_burst(1) if True else build_spec(2)
    def run(self):
        while True:
            out = self._burst()
"""

_DENYLISTED = """
for name in names:
    model = build_model(cfg)
"""


def _rules(src):
    from repro.analysis.lint import lint_source
    return [f.rule for f in lint_source(src)]


def test_hot_path_lint_flags_unspanned_loop():
    assert "obs.untimed-hot-path" in _rules(_LOOP)


def test_hot_path_lint_respects_span_scopes():
    assert _rules(_LOOP_SPANNED) == []
    assert _rules(_LOOP_INNER_SPAN) == []


def test_hot_path_lint_waiver():
    assert _rules(_LOOP_WAIVED) == []


def test_hot_path_lint_builder_attribute_and_ifexp():
    assert "obs.untimed-hot-path" in _rules(_BUILDER_ATTR)


def test_hot_path_lint_denylists_model_factories():
    assert _rules(_DENYLISTED) == []


def test_repo_is_hot_path_clean():
    """The repo's own hot loops are all spanned (or waived with a cited
    reason) — the same gate scripts/check.py --lint enforces in CI."""
    from repro.analysis import lint
    bad = [f for f in lint.run() if f.rule == "obs.untimed-hot-path"]
    assert bad == [], bad
