"""Chunked + packed prefill: the attend-at-offset admission contract.

The serving contract under test (DESIGN.md §12):
  * chunk invariance — splitting a prompt's prefill into
    ``ServeConfig.prefill_chunk``-token chunks interleaved with decode
    bursts changes NOTHING about the greedy outputs, across the dense,
    paged, paged+prefix, fp2fx8, and speculative serving paths and across
    the attention / SSM / hybrid / encdec families;
  * packing — multiple prefilling slots share one bucketed chunk call;
    feeding one prompt at a time (``pack_prefill=False``) produces the
    same tokens;
  * prefix-hit suffixes longer than one chunk prefill incrementally from
    the matched offset (the cached tokens never touch the model);
  * long prompts span many chunk calls, and the compiled chunk executables
    never exceed the configured chunk width — a prompt longer than any
    single compiled prefill bucket still serves.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import ServeConfig


def _setup(arch="qwen2-1.5b", vocab=64, **kw):
    from repro.configs import get_config, smoke_config
    from repro.models import build_model
    from repro.models.layers import unbox
    cfg = smoke_config(get_config(arch)).with_(
        softmax_impl="hyft16", vocab=vocab, **kw)
    model = build_model(cfg)
    params = unbox(model.init(jax.random.PRNGKey(0)))
    return cfg, model, params


def _requests(cfg, n, rng, plen=(4, 14), max_new=(3, 9)):
    from repro.serve.scheduler import Request
    reqs = []
    for rid in range(n):
        frames = None
        if cfg.family == "encdec":
            frames = np.asarray(jax.random.normal(
                jax.random.fold_in(jax.random.PRNGKey(99), rid),
                (cfg.frontend_len, cfg.frontend_dim)))
        reqs.append(Request(
            rid=rid,
            tokens=rng.integers(0, cfg.vocab,
                                int(rng.integers(*plen))).astype(np.int32),
            max_new=int(rng.integers(*max_new)),
            frames=frames))
    return reqs


def _serve(model, params, reqs, scfg):
    from repro.serve.scheduler import SlotPoolEngine
    eng = SlotPoolEngine(model, params, scfg)
    done = eng.run(reqs)
    return {rid: c.tokens for rid, c in done.items()}, eng


def _solo(model, params, req, scfg):
    from repro.serve.engine import generate
    batch = {"tokens": np.asarray(req.tokens)[None]}
    if req.frames is not None:
        batch["frames"] = np.asarray(req.frames)[None]
    out = generate(model, params, batch, scfg, max_new=req.max_new)
    return np.asarray(out)[0].tolist()


# --------------------------------------------------------------------------
# chunk invariance across families
# --------------------------------------------------------------------------


@pytest.mark.parametrize(
    "arch", ["qwen2-1.5b", "whisper-medium", "mamba2-370m", "zamba2-7b"])
def test_chunked_matches_whole_prompt_and_solo(arch):
    """chunk=4 admission (multi-chunk prompts interleaved with bursts) ==
    whole-prompt admission == each prompt's solo greedy run — attention,
    encdec, SSM, and hybrid families."""
    cfg, model, params = _setup(arch)
    reqs = _requests(cfg, 5, np.random.default_rng(0))
    outs = {}
    for chunk in (0, 4):
        scfg = ServeConfig(max_len=32, cache_dtype="float32",
                           scheduler="continuous", n_slots=3, decode_burst=4,
                           prefill_chunk=chunk)
        outs[chunk], eng = _serve(model, params, reqs, scfg)
    assert outs[4] == outs[0]
    solo_cfg = ServeConfig(max_len=32, cache_dtype="float32")
    for r in reqs:
        assert len(outs[4][r.rid]) == r.max_new
        assert outs[4][r.rid] == _solo(model, params, r, solo_cfg), r.rid


@pytest.mark.parametrize("kw", [
    dict(cache_dtype="fp2fx8"),
    dict(kv_layout="paged", page_size=4),
    dict(kv_layout="paged", page_size=4, prefix_cache=True),
    dict(scheduler="spec", draft_k=3),
], ids=["fp2fx8", "paged", "paged_prefix", "spec"])
def test_chunked_matches_across_serving_paths(kw):
    """chunk=4 vs whole-prompt parity over the quantized-cache, paged,
    prefix-cached, and speculative serving paths (same primitive under
    all of them)."""
    cfg, model, params = _setup()
    reqs = _requests(cfg, 6, np.random.default_rng(1))
    outs = {}
    for chunk in (0, 4):
        scfg = ServeConfig(max_len=32,
                           cache_dtype=kw.get("cache_dtype", "float32"),
                           scheduler=kw.get("scheduler", "continuous"),
                           n_slots=3, decode_burst=4, prefill_chunk=chunk,
                           kv_layout=kw.get("kv_layout", "dense"),
                           page_size=kw.get("page_size", 16),
                           prefix_cache=kw.get("prefix_cache", False),
                           draft_k=kw.get("draft_k", 4))
        outs[chunk], _ = _serve(model, params, reqs, scfg)
    assert outs[4] == outs[0]


def test_unpacked_prefill_matches_packed():
    """pack_prefill=False (one prompt at a time, arrival order) emits the
    same tokens as the packed one-call-per-step default — per-row lane
    arithmetic is independent of who shares the call."""
    cfg, model, params = _setup()
    reqs = _requests(cfg, 5, np.random.default_rng(2))
    outs = {}
    for pack in (True, False):
        scfg = ServeConfig(max_len=32, cache_dtype="float32",
                           scheduler="continuous", n_slots=3, decode_burst=4,
                           prefill_chunk=4, pack_prefill=pack)
        outs[pack], _ = _serve(model, params, reqs, scfg)
    assert outs[False] == outs[True]


# --------------------------------------------------------------------------
# prefix-hit suffixes and long prompts
# --------------------------------------------------------------------------


def test_prefix_hit_suffix_longer_than_one_chunk():
    """A follower whose un-cached suffix spans several chunks prefills
    incrementally from the matched offset: the cached head never re-enters
    the model, and the outputs still match the solo run."""
    from repro.serve.scheduler import Request, SlotPoolEngine
    cfg, model, params = _setup()
    rng = np.random.default_rng(3)
    head = rng.integers(0, cfg.vocab, 12).astype(np.int32)
    leader = Request(rid=0, tokens=head, max_new=3)
    follower = Request(rid=1, tokens=np.concatenate(
        [head, rng.integers(0, cfg.vocab, 11).astype(np.int32)]), max_new=5)
    scfg = ServeConfig(max_len=40, cache_dtype="float32",
                       scheduler="continuous", n_slots=2, decode_burst=4,
                       kv_layout="paged", page_size=4, prefix_cache=True,
                       prefill_chunk=4)
    eng = SlotPoolEngine(model, params, scfg)
    # deterministic drive (run()'s admission depends on wall-clock
    # arrivals): finish the leader so its pages are published, THEN admit
    # the follower — its 11-token suffix spans three width-4 chunks
    eng.admit([leader], 0.0)
    while eng.prefilling.any():
        eng._prefill_step(0.0)
    while eng.active.any():
        eng.burst(0.0)
    pre = eng.stats["prefills"]
    eng.admit([follower], 0.0)
    assert eng.stats["prefix_hits"] == 1
    assert eng.stats["cached_tokens"] == 12   # three full cached pages
    assert int(eng.lengths[[s for s, rid in enumerate(eng.slot_rid)
                            if rid == 1][0]]) == 12  # starts at the match
    while eng.prefilling.any():
        eng._prefill_step(0.0)
    assert eng.stats["prefills"] - pre >= 3   # ceil(11 / 4) suffix chunks
    while eng.active.any():
        eng.burst(0.0)
    solo_cfg = ServeConfig(max_len=40, cache_dtype="float32")
    for r in (leader, follower):
        assert eng.completions[r.rid].tokens == _solo(model, params, r,
                                                      solo_cfg), r.rid


def test_long_prompt_spans_many_chunks_with_bounded_buckets():
    """A 56-token prompt under chunk=8 takes >= 7 chunk calls, and no
    chunk executable wider than the chunk size is ever compiled — the
    property that makes prompts longer than any single compiled prefill
    bucket servable."""
    from repro.serve import engine
    from repro.serve.scheduler import Request
    cfg, model, params = _setup()
    rng = np.random.default_rng(4)
    req = Request(rid=0, tokens=rng.integers(0, cfg.vocab, 56).astype(
        np.int32), max_new=5)
    before = set(engine._CHUNK_CACHE)
    scfg = ServeConfig(max_len=64, cache_dtype="float32",
                       scheduler="continuous", n_slots=2, decode_burst=4,
                       prefill_chunk=8)
    outs, eng = _serve(model, params, [req], scfg)
    assert eng.stats["prefills"] >= 7         # ceil(56 / 8)
    new_widths = {k[-1] for k in set(engine._CHUNK_CACHE) - before}
    assert new_widths and max(new_widths) <= 8
    solo_cfg = ServeConfig(max_len=64, cache_dtype="float32")
    assert outs[0] == _solo(model, params, req, solo_cfg)


def test_prefill_interleaves_with_decode():
    """While a long prompt chunk-prefills, an already-active short request
    keeps emitting tokens between the chunks — the decode stall is bounded
    by one chunk, which is the whole point."""
    from repro.serve.scheduler import Request, SlotPoolEngine
    cfg, model, params = _setup()
    rng = np.random.default_rng(5)
    short = Request(rid=0, tokens=rng.integers(0, cfg.vocab, 4).astype(
        np.int32), max_new=12)
    long_ = Request(rid=1, tokens=rng.integers(0, cfg.vocab, 40).astype(
        np.int32), max_new=4)
    scfg = ServeConfig(max_len=48, cache_dtype="float32",
                       scheduler="continuous", n_slots=2, decode_burst=2,
                       prefill_chunk=4)
    eng = SlotPoolEngine(model, params, scfg)
    # deterministic drive: activate the short request, then admit the long
    # one and step the loop by hand — every prefill chunk is followed by a
    # decode burst that advances the short request
    eng.admit([short], 0.0)
    eng._prefill_step(0.0)
    eng.admit([long_], 0.0)
    grew = 0
    while eng.prefilling.any():
        n0 = len(eng.outputs[0])
        eng._prefill_step(0.0)
        if eng.active[0]:
            eng.burst(0.0)
            grew += len(eng.outputs[0]) > n0
    assert grew >= 3                          # decode advanced mid-prefill
    while eng.active.any():
        eng.burst(0.0)
    solo_cfg = ServeConfig(max_len=48, cache_dtype="float32")
    for r in (short, long_):
        assert eng.completions[r.rid].tokens == _solo(model, params, r,
                                                      solo_cfg), r.rid
