"""Trainable, mask-aware fused flash kernel: backward parity + decode path.

The fused kernel's VJP (two Pallas kernels recomputing Hyft probabilities
from the saved (m, l) row stats) must match the chunked custom-VJP path —
same arithmetic, so near-bitwise when the KV block sizes agree — and stay
within the Hyft quantization envelope of ``jax.grad`` through the unfused
``hyft_softmax`` path.  Masked decode (the serving scenario) must run on the
fused kernel end to end, with zero gradient leaking into masked positions.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hyft import HYFT16, HYFT32
from repro.kernels import ops
from repro.kernels.flash_attention import flash_hyft_attention
from repro.models.attention import chunked_hyft_attention, unfused_attention

F32 = jnp.float32
KEY = jax.random.PRNGKey(7)


def _qkvw(B=1, Hq=4, Hkv=2, Sq=128, Sk=128, D=32):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, Hq, Sq, D), F32)
    k = jax.random.normal(ks[1], (B, Hkv, Sk, D), F32)
    v = jax.random.normal(ks[2], (B, Hkv, Sk, D), F32)
    w = jax.random.normal(ks[3], (B, Hq, Sq, D), F32)
    return q, k, v, w


@pytest.mark.parametrize("cfg", [HYFT16, HYFT32], ids=["h16", "h32"])
@pytest.mark.parametrize("causal", [True, False])
def test_kernel_grad_matches_chunked(cfg, causal):
    """Same KV blocking => same (m, l) stats => near-identical gradients
    (only fp32 matmul association differs)."""
    q, k, v, w = _qkvw()

    def f_kernel(q, k, v):
        o = flash_hyft_attention(q, k, v, cfg, causal=causal, block_q=64,
                                 block_k=64, interpret=True)
        return jnp.sum(o * w)

    def f_chunked(q, k, v):
        return jnp.sum(chunked_hyft_attention(q, k, v, cfg, causal, 64, 0) * w)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gc = jax.grad(f_chunked, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gc):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=1e-4)


@pytest.mark.parametrize("cfg", [HYFT16, HYFT32], ids=["h16", "h32"])
def test_kernel_grad_close_to_unfused_softmax_grad(cfg):
    """jax.grad through attn_mode="kernel" vs jax.grad of the unfused
    hyft_softmax path — bounded by the Hyft quantization envelope already
    used for the chunked path."""
    q, k, v, _ = _qkvw(Hq=2, Hkv=2, Sq=64, Sk=64, D=16)

    def f_kernel(q, k, v):
        return jnp.sum(flash_hyft_attention(q, k, v, cfg, causal=True,
                                            block_q=32, block_k=32,
                                            interpret=True))

    def f_unfused(q, k, v):
        return jnp.sum(unfused_attention(q, k, v, "hyft32" if cfg is HYFT32
                                         else "hyft16", causal=True))

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gu = jax.grad(f_unfused, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gu):
        assert float(jnp.abs(a - b).max()) < 0.35


@pytest.mark.parametrize("cfg", [HYFT16, HYFT32], ids=["h16", "h32"])
def test_masked_decode_grad_matches_chunked(cfg):
    """Masked non-causal (decode/serving) gradients: fused kernel == chunked
    path under the shared mask contract; no gradient at masked positions."""
    q, k, v, w = _qkvw(B=2, Hq=4, Hkv=2, Sq=8, Sk=64, D=16)
    valid = 40
    maskf = (jnp.arange(64)[None, :] < valid).astype(F32).repeat(2, 0)

    def f_kernel(q, k, v):
        o = flash_hyft_attention(q, k, v, cfg, causal=False, block_q=8,
                                 block_k=32, interpret=True, kv_len_mask=maskf)
        return jnp.sum(o * w)

    def f_chunked(q, k, v):
        return jnp.sum(
            chunked_hyft_attention(q, k, v, cfg, False, 32, 0, maskf) * w)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gc = jax.grad(f_chunked, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gc):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=1e-4)
    # masked KV positions receive (at most) negligible dk/dv: Hyft16's
    # narrow fixed range leaves a ~2**-105 residual probability; Hyft32
    # flushes to exactly zero
    assert float(jnp.abs(gk[1][:, :, valid:]).max()) < 1e-12
    assert float(jnp.abs(gk[2][:, :, valid:]).max()) < 1e-12


def test_masked_fwd_matches_unfused():
    """Fused forward with kv_len_mask stays within the log-div Taylor bound
    of the unfused masked path (same bound as the sp-decode test)."""
    q, k, v, _ = _qkvw(B=2, Hq=4, Hkv=2, Sq=1, Sk=64, D=16)
    valid = jnp.arange(64)[None, :].repeat(2, 0) < 40
    o = ops.hyft_attention(q, k, v, HYFT32, causal=False, kv_len_mask=valid)
    o_ref = unfused_attention(q, k, v, "hyft32", causal=False,
                              kv_len_mask=valid)
    assert float(jnp.abs(o - o_ref).max()) < 0.06


def test_nonmultiple_lengths_auto_padded():
    """Sequence lengths that don't divide the block sizes are padded inside
    the wrapper and produce the same result as smaller exact blocks."""
    q, k, v, _ = _qkvw(Sq=96, Sk=200, D=16)
    a = flash_hyft_attention(q, k, v, HYFT32, causal=False, block_q=64,
                             block_k=128, interpret=True)
    b = flash_hyft_attention(q, k, v, HYFT32, causal=False, block_q=32,
                             block_k=8, interpret=True)
    # same elementwise Hyft math; only the online merge order differs
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-2)
    assert a.shape == (1, 4, 96, 16)


def test_q_offset_matches_full_causal():
    """A partial-prefill continuation (q_offset > 0) equals the suffix rows
    of the full causal computation."""
    q, k, v, _ = _qkvw(Sq=64, Sk=64, D=16)
    full = flash_hyft_attention(q, k, v, HYFT32, causal=True, block_q=32,
                                block_k=32, interpret=True)
    tail = flash_hyft_attention(q[:, :, 32:], k, v, HYFT32, causal=True,
                                block_q=32, block_k=32, interpret=True,
                                q_offset=32)
    np.testing.assert_allclose(np.asarray(tail), np.asarray(full[:, :, 32:]),
                               atol=1e-6)


class TestEngineOnFusedKernel:
    """serve/engine decode with attn_mode="kernel" never touches the unfused
    fallback — the acceptance criterion for the serving path."""

    def _model(self, attn_mode):
        from repro.configs.base import ModelConfig
        from repro.models import build_model
        cfg = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=32,
                          n_heads=4, n_kv_heads=2, d_head=8, d_ff=64,
                          vocab=64, softmax_impl="hyft32",
                          attn_mode=attn_mode, compute_dtype="float32")
        return build_model(cfg)

    def test_decode_no_unfused_fallback(self, monkeypatch):
        from repro.configs.base import ServeConfig
        from repro.models import attention as attn_mod
        from repro.models.layers import unbox
        from repro.serve.engine import generate

        model = self._model("kernel")
        params = unbox(model.init(jax.random.PRNGKey(0)))

        def boom(*a, **kw):
            raise AssertionError("masked decode fell back to unfused")
        monkeypatch.setattr(attn_mod, "unfused_attention", boom)

        batch = {"tokens": jax.random.randint(
            jax.random.PRNGKey(1), (2, 8), 0, 64, jnp.int32)}
        scfg = ServeConfig(batch=2, prefill_len=8, max_len=16,
                           cache_dtype="float32")
        out = generate(model, params, batch, scfg, max_new=4)
        assert out.shape == (2, 4)

    def test_serve_config_attn_mode_override(self, monkeypatch):
        """ServeConfig.attn_mode="kernel" upgrades an unfused model at the
        engine boundary (the launch/serve plumbing)."""
        from repro.configs.base import ServeConfig
        from repro.models import attention as attn_mod
        from repro.models.layers import unbox
        from repro.serve.engine import generate

        model = self._model("unfused")
        params = unbox(model.init(jax.random.PRNGKey(0)))

        def boom(*a, **kw):
            raise AssertionError("override did not reach the fused kernel")
        monkeypatch.setattr(attn_mod, "unfused_attention", boom)

        batch = {"tokens": jax.random.randint(
            jax.random.PRNGKey(1), (2, 4), 0, 64, jnp.int32)}
        scfg = ServeConfig(batch=2, prefill_len=4, max_len=10,
                           cache_dtype="float32", attn_mode="kernel")
        out = generate(model, params, batch, scfg, max_new=3)
        assert out.shape == (2, 3)


def test_train_step_attn_mode_override():
    """TrainConfig.attn_mode="kernel" trains through the fused fwd+bwd
    kernels (the train/step plumbing)."""
    import repro.optim as optim
    from repro.configs.base import ModelConfig, TrainConfig
    from repro.models import build_model
    from repro.models.layers import unbox
    from repro.train.step import make_step_fn

    cfg = ModelConfig(name="tiny", family="dense", n_layers=1, d_model=16,
                      n_heads=2, n_kv_heads=2, d_head=8, d_ff=32, vocab=32,
                      softmax_impl="hyft32", attn_mode="unfused",
                      compute_dtype="float32")
    model = build_model(cfg)
    params = unbox(model.init(jax.random.PRNGKey(0)))
    tcfg = TrainConfig(global_batch=2, seq_len=8, total_steps=2, remat="none",
                       attn_mode="kernel")
    ocfg = optim.OptConfig(name="adamw", lr=1e-3)
    step = make_step_fn(model, tcfg, ocfg)
    state = {"params": params, "opt": optim.init(ocfg, params),
             "step": jnp.zeros((), jnp.int32), "rng": jax.random.PRNGKey(0)}
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, 32, jnp.int32)
    state, metrics = step(state, {"tokens": toks, "targets": toks})
    assert jnp.isfinite(metrics["loss"])
