"""Serving robustness layer (DESIGN.md §13): deadlines, cancellation,
bounded queues/retries, numeric-health degradation, pool/trie audits, and
the seeded fault-injection harness.

The contract under test:
  * a deadline-expired request fails with reason ``deadline`` and frees its
    slot and pages within one burst (device TTL) or at the next scheduling
    checkpoint (host sweep) — never hangs;
  * admission backpressure rejects with reason ``queue_full`` once the
    bounded queue is full, without touching the rest of the batch;
  * host ``cancel(rid)`` lands between bursts: a partial Completion with
    ``cancelled=True`` whose tokens are a prefix of the solo run;
  * NaN/Inf KV poison is quarantined to exactly the faulted slot and the
    degradation ladder recovers: requeue-and-recompute first (greedy
    outputs token-identical to fault-free), one unfused-fp32 retry on a
    repeat fault, a structured ``numeric_fault`` after that;
  * ``max_retries`` converts requeue livelock into ``retries_exhausted``;
  * refcount audits catch double-holds and freed-slot leaks at the
    mutation that caused them;
  * drafter desync is rejected by exact verification — outputs provably
    unchanged;
  * ``shutdown()`` drains every in-flight/queued request as a cancelled
    partial Completion (the graceful KeyboardInterrupt path).
"""
import jax
import numpy as np
import pytest

from repro.configs.base import ServeConfig
from repro.serve.chaos import ChaosMonkey, FaultPlan
from repro.serve.kvpool import AuditError, PagePool, RadixTrie


def _setup(vocab=64, **kw):
    from repro.configs import get_config, smoke_config
    from repro.models import build_model
    from repro.models.layers import unbox
    cfg = smoke_config(get_config("qwen2-1.5b")).with_(
        softmax_impl="hyft16", vocab=vocab, **kw)
    model = build_model(cfg)
    params = unbox(model.init(jax.random.PRNGKey(0)))
    return cfg, model, params


def _requests(cfg, n, rng, plen=(3, 9), max_new=(3, 9), **kw):
    from repro.serve.scheduler import Request
    return [Request(
        rid=rid,
        tokens=rng.integers(0, cfg.vocab, int(rng.integers(*plen))).astype(
            np.int32),
        max_new=int(rng.integers(*max_new)), **kw) for rid in range(n)]


def _solo(model, params, req, max_len=32):
    import jax.numpy as jnp
    from repro.serve.engine import generate
    out = generate(model, params, {"tokens": jnp.asarray(req.tokens)[None]},
                   ServeConfig(max_len=max_len, cache_dtype="float32"),
                   max_new=req.max_new)
    return np.asarray(out)[0].tolist()


# --------------------------------------------------------------------------
# deadlines
# --------------------------------------------------------------------------


def test_deadline_expires_in_queue_and_in_slot():
    """One slot, two requests: the occupant outlives the waiter's deadline,
    so the waiter expires IN THE QUEUE with a structured ``deadline``
    failure — and a deadlined occupant is expired by the host sweep —
    while the survivor's output never changes."""
    from repro.serve.scheduler import SlotPoolEngine
    cfg, model, params = _setup()
    rng = np.random.default_rng(0)
    reqs = _requests(cfg, 2, rng, plen=(4, 5), max_new=(10, 11))
    reqs[1] = type(reqs[1])(rid=1, tokens=reqs[1].tokens,
                            max_new=reqs[1].max_new, deadline=1e-4)
    scfg = ServeConfig(max_len=32, cache_dtype="float32",
                       scheduler="continuous", n_slots=1, decode_burst=4)
    eng = SlotPoolEngine(model, params, scfg)
    done = eng.run(reqs)
    assert set(done) == {0, 1}
    assert done[0].ok
    assert done[0].tokens == _solo(model, params, reqs[0])
    assert not done[1].ok and done[1].failure.reason == "deadline"
    assert eng.stats["expired"] == 1
    assert not eng.active.any() and not eng.prefilling.any()


def test_deadline_ttl_frees_slot_and_pages_within_one_burst():
    """Device-side TTL: with a warm per-step estimate, a deadlined slot's
    burst allowance is floored at the deadline — the slot frees ON DEVICE
    partway through the burst, its pages return to the pool, and the
    completion carries the ``deadline`` failure with the tokens emitted up
    to the cutoff."""
    from repro.serve.scheduler import Request, SlotPoolEngine
    cfg, model, params = _setup()
    rng = np.random.default_rng(1)
    req = Request(rid=0,
                  tokens=rng.integers(0, cfg.vocab, 5).astype(np.int32),
                  max_new=12, deadline=0.5)
    scfg = ServeConfig(max_len=32, cache_dtype="float32",
                       scheduler="continuous", n_slots=2, decode_burst=8,
                       kv_layout="paged", page_size=4, audit=True)
    eng = SlotPoolEngine(model, params, scfg)
    eng.admit([req], 0.0)
    eng._prefill_step(0.0)
    assert eng.active.any()
    # warm step estimate of 1 s/step: remaining 0.5s -> TTL clips to 1
    eng._step_ema = 1.0
    eng.burst(0.0)
    comp = eng.completions[0]
    assert comp.failure is not None and comp.failure.reason == "deadline"
    # one admission token + one burst step before the TTL hit — the burst
    # was cut short, not run to the full decode_burst or budget
    assert 1 <= len(comp.tokens) <= 2
    assert not eng.active.any()
    assert eng.pool.pages_in_use == 0       # pages freed with the slot
    assert eng.stats["expired"] == 1


# --------------------------------------------------------------------------
# backpressure / bounded retries
# --------------------------------------------------------------------------


def test_bounded_queue_rejects_with_queue_full():
    from repro.serve.scheduler import SlotPoolEngine
    cfg, model, params = _setup()
    reqs = _requests(cfg, 4, np.random.default_rng(2), max_new=(3, 4))
    scfg = ServeConfig(max_len=32, cache_dtype="float32",
                       scheduler="continuous", n_slots=1, decode_burst=2,
                       max_queue=1)
    eng = SlotPoolEngine(model, params, scfg)
    done = eng.run(reqs)
    assert set(done) == {0, 1, 2, 3}
    rejected = [c for c in done.values()
                if c.failure is not None and c.failure.reason == "queue_full"]
    served = [c for c in done.values() if c.ok]
    # all four arrive at t=0 and drain into the queue BEFORE admission
    # pops it: the first fills the one queue seat, the rest reject
    assert len(rejected) == 3 and eng.stats["rejected"] == 3
    assert len(served) == 1
    for c in served:
        assert c.tokens == _solo(model, params, reqs[c.rid])


def test_retries_exhausted_is_a_definite_outcome():
    """``max_retries=0``: the first requeue attempt (here from a forced
    numeric quarantine) fails structurally instead of looping."""
    from repro.serve.scheduler import SlotPoolEngine
    cfg, model, params = _setup()
    reqs = _requests(cfg, 1, np.random.default_rng(3), max_new=(8, 9))
    scfg = ServeConfig(max_len=32, cache_dtype="float32",
                       scheduler="continuous", n_slots=1, decode_burst=4,
                       max_retries=0)
    monkey = ChaosMonkey(FaultPlan(seed=0, nan_kv_rate=1.0, max_faults=1))
    eng = SlotPoolEngine(model, params, scfg, chaos=monkey)
    done = eng.run(reqs)
    c = done[0]
    assert c.failure is not None and c.failure.reason == "retries_exhausted"
    assert eng.stats["quarantines"] == 1
    assert not eng.active.any()


# --------------------------------------------------------------------------
# cancellation / shutdown
# --------------------------------------------------------------------------


def test_cancel_mid_run_returns_partial_prefix():
    from repro.serve.scheduler import SlotPoolEngine
    cfg, model, params = _setup()
    rng = np.random.default_rng(4)
    reqs = _requests(cfg, 1, rng, plen=(4, 5), max_new=(12, 13))
    scfg = ServeConfig(max_len=32, cache_dtype="float32",
                       scheduler="continuous", n_slots=2, decode_burst=4)
    eng = SlotPoolEngine(model, params, scfg)
    eng.admit(reqs, 0.0)
    eng._prefill_step(0.0)
    eng.burst(0.0)                       # a few tokens in flight
    eng.cancel(0)
    eng.cancel(99)                       # unknown rid: ignored, no crash
    eng._apply_cancels(0.0)
    c = eng.completions[0]
    assert c.cancelled and not c.ok
    solo = _solo(model, params, reqs[0])
    assert 0 < len(c.tokens) < len(solo)
    assert c.tokens == solo[:len(c.tokens)]   # partial = prefix of solo
    assert not eng.active.any() and eng.stats["cancelled"] == 1


def test_shutdown_drains_everything_as_cancelled():
    """The graceful KeyboardInterrupt path: one decoding slot + two queued
    requests all surface as cancelled partials, pages return to the pool,
    and a second shutdown() is a no-op."""
    from repro.serve.scheduler import SlotPoolEngine
    cfg, model, params = _setup()
    rng = np.random.default_rng(5)
    reqs = _requests(cfg, 3, rng, plen=(4, 5), max_new=(10, 11))
    scfg = ServeConfig(max_len=32, cache_dtype="float32",
                       scheduler="continuous", n_slots=1, decode_burst=4,
                       kv_layout="paged", page_size=4, prefix_cache=True,
                       audit=True)
    eng = SlotPoolEngine(model, params, scfg)
    eng.admit([reqs[0]], 0.0)
    eng._queue.extend(reqs[1:])
    eng._prefill_step(0.0)
    eng.burst(0.0)
    done = eng.shutdown()
    assert set(done) == {0, 1, 2}
    assert all(c.cancelled for c in done.values())
    assert len(done[0].tokens) > 0           # in-flight keeps partial work
    assert done[1].tokens == [] and done[2].tokens == []
    # all slot-held pages returned; only the trie's cached prefixes remain
    assert eng.pool.pages_in_use == eng.trie.n_pages() and not eng._queue
    assert eng.shutdown() is done or eng.shutdown() == done   # idempotent


# --------------------------------------------------------------------------
# numeric-health degradation ladder
# --------------------------------------------------------------------------


@pytest.mark.parametrize("cache_dtype,layout", [
    ("float32", "dense"),
    ("fp2fx8", "dense"),      # poison lands in the fp32 scale rows
    ("float32", "paged"),     # poison lands in an exclusive frontier page
])
def test_nan_poison_quarantines_and_recovers_greedy(cache_dtype, layout):
    """One injected NaN: the faulted slot is quarantined (finite-prefix
    tokens kept), requeued, and recomputed — final outputs token-identical
    to a fault-free run for EVERY request, the poisoned one included."""
    from repro.serve.scheduler import SlotPoolEngine
    cfg, model, params = _setup()
    reqs = _requests(cfg, 2, np.random.default_rng(6), plen=(4, 7),
                     max_new=(8, 11))
    kw = dict(kv_layout=layout)
    if layout == "paged":
        kw.update(page_size=4, prefix_cache=True, audit=True)
    scfg = ServeConfig(max_len=32, cache_dtype=cache_dtype,
                       scheduler="continuous", n_slots=2, decode_burst=4,
                       **kw)
    base = SlotPoolEngine(model, params, scfg).run(reqs)
    monkey = ChaosMonkey(FaultPlan(seed=0, nan_kv_rate=1.0, max_faults=1))
    eng = SlotPoolEngine(model, params, scfg, chaos=monkey)
    done = eng.run(reqs)
    assert eng.stats["quarantines"] == 1
    assert len(monkey.faulted_rids) == 1
    for r in reqs:
        assert done[r.rid].ok
        assert done[r.rid].tokens == base[r.rid].tokens, f"rid={r.rid}"
    if layout == "paged":
        # slots all drained: only the trie's cached prefixes hold pages
        assert eng.pool.pages_in_use == eng.trie.n_pages()
        assert eng.stats["audits"] > 0


def test_repeat_fault_walks_to_fp32_retry():
    """Poison the same request twice: first fault requeues, second goes to
    the one-shot unfused-fp32 retry, which completes it — full budget, no
    failure, and the retry is counted."""
    from repro.serve.scheduler import SlotPoolEngine
    cfg, model, params = _setup()
    reqs = _requests(cfg, 1, np.random.default_rng(7), max_new=(6, 7))
    scfg = ServeConfig(max_len=32, cache_dtype="float32",
                       scheduler="continuous", n_slots=1, decode_burst=4)
    monkey = ChaosMonkey(FaultPlan(seed=0, nan_kv_rate=1.0, max_faults=2))
    eng = SlotPoolEngine(model, params, scfg, chaos=monkey)
    done = eng.run(reqs)
    c = done[0]
    assert eng.stats["quarantines"] == 2
    assert eng.stats["fp32_retries"] == 1
    assert c.ok and len(c.tokens) == reqs[0].max_new
    toks = np.array(c.tokens)
    assert np.all((toks >= 0) & (toks < cfg.vocab))


# --------------------------------------------------------------------------
# audits catch corruption
# --------------------------------------------------------------------------


def test_pool_audit_catches_refcount_drift():
    pool = PagePool(8)
    a = pool.alloc(3)
    pool.audit([a])                      # clean
    pool.refs[a[0]] += 1                 # simulated double-incref drift
    with pytest.raises(AuditError):
        pool.audit([a])
    pool.refs[a[0]] -= 1
    with pytest.raises(AuditError):      # holder the books don't explain
        pool.audit([])
    pool.audit([a[:1], a[1:]])           # split across holders still adds up


def test_trie_audit_catches_freed_shared_page():
    pool = PagePool(8)
    trie = RadixTrie(pool, 4)
    pages = pool.alloc(2)
    trie.insert(list(range(8)), pages)
    trie.audit()
    pool.audit([pages], trie)
    pool.decref(pages[0])                # drop the slot's ref: trie holds it
    pool.decref(pages[0])                # drop the TRIE's ref out from under
    with pytest.raises(AuditError):
        trie.audit()


def test_engine_audit_catches_freed_slot_page_leak():
    from repro.serve.scheduler import SlotPoolEngine
    cfg, model, params = _setup()
    reqs = _requests(cfg, 1, np.random.default_rng(8))
    scfg = ServeConfig(max_len=32, cache_dtype="float32",
                       scheduler="continuous", n_slots=2, decode_burst=4,
                       kv_layout="paged", page_size=4, audit=True)
    eng = SlotPoolEngine(model, params, scfg)
    eng.admit(reqs, 0.0)
    eng._prefill_step(0.0)
    s = next(i for i in range(scfg.n_slots) if eng.slot_pages[i])
    eng.slot_rid[s] = None               # simulated bookkeeping bug
    with pytest.raises(AuditError):
        eng._audit_check()


# --------------------------------------------------------------------------
# drafter desync / full chaos sweeps
# --------------------------------------------------------------------------


def test_drafter_desync_never_changes_outputs():
    """Junk drafts at rate 1.0: exact verification rejects them, so the
    speculative outputs stay identical to the fault-free spec run — the
    fault only costs acceptance."""
    from repro.serve.scheduler import Request, SlotPoolEngine
    cfg, model, params = _setup()
    rng = np.random.default_rng(9)
    reqs = []
    for i in range(3):
        motif = rng.integers(0, cfg.vocab, 4).astype(np.int32)
        reqs.append(Request(
            rid=i,
            tokens=np.concatenate(
                [np.tile(motif, 3),
                 rng.integers(0, cfg.vocab, 2).astype(np.int32)]),
            max_new=8))
    scfg = ServeConfig(max_len=32, cache_dtype="float32", scheduler="spec",
                       n_slots=2, decode_burst=4, draft_k=4)
    base = SlotPoolEngine(model, params, scfg).run(reqs)
    monkey = ChaosMonkey(FaultPlan(seed=0, drafter_junk_rate=1.0))
    eng = SlotPoolEngine(model, params, scfg, chaos=monkey)
    done = eng.run(reqs)
    assert any(e["kind"] == "drafter_junk" for e in monkey.log)
    for r in reqs:
        assert done[r.rid].ok
        assert done[r.rid].tokens == base[r.rid].tokens


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["paged", "spec"])
def test_chaos_sweep_definite_outcomes_and_identity(mode):
    """A mixed seeded FaultPlan over a full run: every request terminates
    with a definite outcome, audits stay clean (the run itself would raise
    AuditError otherwise), and every ok completion whose KV was never
    poisoned matches the fault-free run token for token."""
    from repro.serve.scheduler import SlotPoolEngine
    cfg, model, params = _setup()
    rng = np.random.default_rng(10)
    reqs = _requests(cfg, 8, rng, plen=(4, 10), max_new=(6, 14))
    if mode == "paged":
        kw = dict(kv_layout="paged", page_size=4, prefix_cache=True)
        plan = FaultPlan(seed=1, preempt_rate=0.1, evict_storm_rate=0.1,
                         squeeze_rate=0.1, squeeze_hold=2, nan_kv_rate=0.1,
                         cancel_rate=0.03, max_faults=8)
    else:
        kw = dict(scheduler="spec", draft_k=4)
        plan = FaultPlan(seed=1, drafter_junk_rate=0.3, preempt_rate=0.1,
                         cancel_rate=0.03, max_faults=8)
    scfg = ServeConfig(max_len=32, cache_dtype="float32",
                       scheduler=kw.pop("scheduler", "continuous"),
                       n_slots=3, decode_burst=4, audit=True, **kw)
    base = SlotPoolEngine(model, params, scfg).run(reqs)
    monkey = ChaosMonkey(plan)
    eng = SlotPoolEngine(model, params, scfg, chaos=monkey)
    done = eng.run(reqs)
    assert set(done) == {r.rid for r in reqs}       # definite outcomes
    assert monkey.n_faults > 0
    for rid, c in done.items():
        if c.ok and rid not in monkey.faulted_rids:
            assert c.tokens == base[rid].tokens, f"rid={rid}"
    if mode == "paged":
        # slots all drained: only the trie's cached prefixes hold pages
        assert eng.pool.pages_in_use == eng.trie.n_pages()
        assert eng.stats["audits"] > 0
