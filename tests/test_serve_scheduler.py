"""Continuous batching: slot-pool scheduler, ragged prefill, EOS early-exit.

The serving contract under test:
  * ragged parity — every request served through the slot pool (padded
    prompts, shared cache, insertion prefill, masked bursts) produces
    token-for-token the same greedy output as a solo ``engine.generate``
    run of that prompt alone;
  * EOS frees a slot ON DEVICE and stops its cache writes mid-burst while
    neighbouring slots keep decoding;
  * freed slots are reused (more requests than slots);
  * the pool works over the fp2fx8 int8 KV-cache layout;
  * the FIRST generated token is sampled when temperature > 0 (it used to
    be unconditionally argmax) — in ``generate`` and in the scheduler.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ServeConfig

F32 = jnp.float32


def _setup(arch="qwen2-1.5b", vocab=64, **kw):
    from repro.configs import get_config, smoke_config
    from repro.models import build_model
    from repro.models.layers import unbox
    cfg = smoke_config(get_config(arch)).with_(
        softmax_impl="hyft16", vocab=vocab, **kw)
    model = build_model(cfg)
    params = unbox(model.init(jax.random.PRNGKey(0)))
    return cfg, model, params


def _requests(cfg, n, rng, plen=(3, 9), max_new=(3, 9)):
    from repro.serve.scheduler import Request
    reqs = []
    for rid in range(n):
        frames = None
        if cfg.family == "encdec":
            frames = np.asarray(jax.random.normal(
                jax.random.fold_in(jax.random.PRNGKey(99), rid),
                (cfg.frontend_len, cfg.frontend_dim)))
        reqs.append(Request(
            rid=rid,
            tokens=rng.integers(0, cfg.vocab, int(rng.integers(*plen))).astype(
                np.int32),
            max_new=int(rng.integers(*max_new)),
            frames=frames))
    return reqs


def _solo(model, params, req, scfg, max_new=None):
    from repro.serve.engine import generate
    batch = {"tokens": jnp.asarray(req.tokens)[None]}
    if req.frames is not None:
        batch["frames"] = jnp.asarray(req.frames)[None]
    out = generate(model, params, batch, scfg,
                   max_new=max_new or req.max_new)
    return np.asarray(out)[0].tolist()


# --------------------------------------------------------------------------
# ragged greedy parity vs solo runs
# --------------------------------------------------------------------------


@pytest.mark.parametrize(
    "arch", ["qwen2-1.5b", "whisper-medium", "mamba2-370m", "zamba2-7b"])
def test_ragged_parity_matches_solo(arch):
    """5 ragged requests through a 3-slot pool (queueing + insertion prefill
    mid-decode) == each prompt's solo greedy run, token for token — across
    the dense, encdec, SSM (gated recurrent state), and hybrid (shared-attn
    cache + gated state) families."""
    from repro.serve.scheduler import SlotPoolEngine
    cfg, model, params = _setup(arch)
    reqs = _requests(cfg, 5, np.random.default_rng(0))
    scfg = ServeConfig(max_len=32, cache_dtype="float32",
                       scheduler="continuous", n_slots=3, decode_burst=4)
    eng = SlotPoolEngine(model, params, scfg)
    done = eng.run(reqs)
    assert eng.stats["admitted"] == 5 and eng.stats["peak_active"] <= 3
    solo_cfg = ServeConfig(max_len=32, cache_dtype="float32")
    for r in reqs:
        got = done[r.rid].tokens
        assert len(got) == r.max_new
        assert got == _solo(model, params, r, solo_cfg), f"rid={r.rid}"


def test_lockstep_mode_same_outputs():
    """The drain-between-groups baseline runs the same burst arithmetic:
    identical greedy outputs, admission policy is the only difference."""
    from repro.serve.scheduler import SlotPoolEngine
    cfg, model, params = _setup()
    reqs = _requests(cfg, 5, np.random.default_rng(1))
    outs = {}
    for mode in ("continuous", "lockstep"):
        scfg = ServeConfig(max_len=32, cache_dtype="float32", scheduler=mode,
                           n_slots=2, decode_burst=4)
        eng = SlotPoolEngine(model, params, scfg)
        done = eng.run(reqs)
        outs[mode] = {rid: c.tokens for rid, c in done.items()}
    assert outs["continuous"] == outs["lockstep"]


# --------------------------------------------------------------------------
# EOS early-exit
# --------------------------------------------------------------------------


def test_eos_frees_slot_and_stops_cache_writes():
    """Pick the EOS id from a probe run so it fires mid-decode for request
    A; serve A next to a long-running B.  A must stop at its EOS while B
    runs to budget, and A's cache region past its final length must stay
    untouched (all zeros) even though B kept decoding — the write_mask
    gating, not just the host loop exit."""
    from repro.serve.scheduler import SlotPoolEngine
    cfg, model, params = _setup()
    rng = np.random.default_rng(2)
    reqs = _requests(cfg, 2, rng, plen=(4, 5), max_new=(12, 13))  # plen=4
    base = ServeConfig(max_len=32, cache_dtype="float32",
                       scheduler="continuous", n_slots=2, decode_burst=4)
    probe = SlotPoolEngine(model, params, base).run(reqs)
    eos = probe[0].tokens[2]          # A's 3rd token -> EOS fires mid-decode
    assert eos not in probe[1].tokens, "degenerate probe: pick another seed"

    scfg = ServeConfig(max_len=32, cache_dtype="float32",
                       scheduler="continuous", n_slots=2, decode_burst=4,
                       eos_id=int(eos))
    eng = SlotPoolEngine(model, params, scfg)
    done = eng.run(reqs)
    a, b = done[0], done[1]
    cut = probe[0].tokens.index(eos) + 1
    assert a.tokens == probe[0].tokens[:cut]      # truncated right after EOS
    assert a.tokens[-1] == eos
    assert len(b.tokens) == reqs[1].max_new       # neighbour unaffected
    assert b.tokens == probe[1].tokens

    # the pool cache beyond A's final length is untouched: prompt 4 tokens
    # (bucketed pad 4, no padding garbage) + the fed-back tokens; everything
    # past lengths[slot_a] must still be zero, while B's slot is written
    # right up to its final length.
    k = np.asarray(eng.cache["blocks"]["k"])      # (layers, slots, H, L, D)
    slot_a = 0 if eng.lengths[0] < eng.lengths[1] else 1
    slot_b = 1 - slot_a
    la, lb = int(eng.lengths[slot_a]), int(eng.lengths[slot_b])
    assert la < lb
    assert np.all(k[:, slot_a, :, la:] == 0.0)
    assert np.all(np.any(k[:, slot_b, :, :lb] != 0.0, axis=(0, 1, 3)))


def test_eos_on_first_token_never_occupies_slot():
    from repro.serve.scheduler import SlotPoolEngine
    cfg, model, params = _setup()
    reqs = _requests(cfg, 1, np.random.default_rng(3), max_new=(8, 9))
    solo = _solo(model, params, reqs[0],
                 ServeConfig(max_len=32, cache_dtype="float32"))
    scfg = ServeConfig(max_len=32, cache_dtype="float32",
                       scheduler="continuous", n_slots=2, eos_id=int(solo[0]))
    eng = SlotPoolEngine(model, params, scfg)
    done = eng.run(reqs)
    assert done[0].tokens == [solo[0]]
    assert eng.stats["bursts"] == 0 and not eng.active.any()


# --------------------------------------------------------------------------
# slot reuse / fp2fx8 pool
# --------------------------------------------------------------------------


def test_slot_reuse_after_free():
    """8 requests through 2 slots: every slot is reused, outputs stay
    correct, and the pool never exceeds its fixed size."""
    from repro.serve.scheduler import SlotPoolEngine
    cfg, model, params = _setup()
    reqs = _requests(cfg, 8, np.random.default_rng(4), max_new=(2, 6))
    scfg = ServeConfig(max_len=32, cache_dtype="float32",
                       scheduler="continuous", n_slots=2, decode_burst=2)
    eng = SlotPoolEngine(model, params, scfg)
    done = eng.run(reqs)
    assert len(done) == 8
    assert eng.stats["peak_active"] <= 2
    assert eng.stats["prefills"] >= 4      # admission waves through 2 slots
    solo_cfg = ServeConfig(max_len=32, cache_dtype="float32")
    for r in reqs:
        assert done[r.rid].tokens == _solo(model, params, r, solo_cfg)


def test_moe_pool_runs_valid():
    """MoE can't promise solo-run parity (capacity-bounded routing is
    batch-global, for the lockstep engine too — see DESIGN.md §9), but the
    slot pool must still serve it: full budgets, in-vocab tokens."""
    from repro.serve.scheduler import SlotPoolEngine
    cfg, model, params = _setup("phi3.5-moe-42b-a6.6b")
    reqs = _requests(cfg, 4, np.random.default_rng(7))
    scfg = ServeConfig(max_len=32, cache_dtype="float32",
                       scheduler="continuous", n_slots=2, decode_burst=4)
    done = SlotPoolEngine(model, params, scfg).run(reqs)
    for r in reqs:
        toks = np.array(done[r.rid].tokens)
        assert toks.shape[0] == r.max_new
        assert np.all((toks >= 0) & (toks < cfg.vocab))


def test_malformed_requests_fail_individually():
    """max_new < 1 and oversized prompt+budget get a structured ``invalid``
    failure each — the rest of the batch serves normally instead of the
    whole run() aborting (DESIGN.md §13)."""
    from repro.serve.scheduler import Request, SlotPoolEngine
    cfg, model, params = _setup()
    scfg = ServeConfig(max_len=16, cache_dtype="float32",
                       scheduler="continuous", n_slots=2)
    eng = SlotPoolEngine(model, params, scfg)
    comps = eng.run([
        Request(rid=0, tokens=np.arange(4, dtype=np.int32), max_new=0),
        Request(rid=1, tokens=np.arange(10, dtype=np.int32), max_new=10),
        Request(rid=2, tokens=np.arange(4, dtype=np.int32), max_new=3),
    ])
    assert set(comps) == {0, 1, 2}
    for rid in (0, 1):
        assert comps[rid].failure is not None
        assert comps[rid].failure.reason == "invalid"
        assert comps[rid].tokens == []
        assert not comps[rid].ok
    assert comps[2].ok and len(comps[2].tokens) == 3
    assert eng.stats["admitted"] == 1 and eng.stats["failures"] == 2


def test_fp2fx8_slot_pool_parity():
    """The slot pool over the int8 FP2FX cache layout: quantized solo runs
    and quantized pool runs agree token for token (same per-(head, position)
    scales regardless of slot placement)."""
    from repro.serve.scheduler import SlotPoolEngine
    cfg, model, params = _setup()
    reqs = _requests(cfg, 4, np.random.default_rng(5))
    scfg = ServeConfig(max_len=32, cache_dtype="fp2fx8",
                       scheduler="continuous", n_slots=2, decode_burst=4)
    eng = SlotPoolEngine(model, params, scfg)
    assert eng.cache["blocks"]["k"].dtype == jnp.int8
    assert "k_scale" in eng.cache["blocks"]
    done = eng.run(reqs)
    solo_cfg = ServeConfig(max_len=32, cache_dtype="fp2fx8")
    for r in reqs:
        assert done[r.rid].tokens == _solo(model, params, r, solo_cfg)


# --------------------------------------------------------------------------
# first-token sampling (the serve/engine.py:126 bugfix)
# --------------------------------------------------------------------------


def test_first_token_is_sampled_when_temperature_positive():
    """With temperature > 0 the first generated token must vary across PRNG
    keys (it used to be argmax of the prefill logits — one value always).
    ``max_new=1`` exercises the early-return path too."""
    from repro.serve.engine import generate
    cfg, model, params = _setup()
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (1, 5), 0,
                                          cfg.vocab, jnp.int32)}
    scfg = ServeConfig(max_len=16, cache_dtype="float32", temperature=50.0)
    firsts = {int(np.asarray(generate(model, params, batch, scfg, max_new=1,
                                      key=jax.random.PRNGKey(s)))[0, 0])
              for s in range(12)}
    assert len(firsts) > 1, "first token still greedy under temperature"
    # greedy stays deterministic across keys
    g = ServeConfig(max_len=16, cache_dtype="float32", temperature=0.0)
    greedy = {int(np.asarray(generate(model, params, batch, g, max_new=1,
                                      key=jax.random.PRNGKey(s)))[0, 0])
              for s in range(4)}
    assert len(greedy) == 1


def test_scheduler_first_token_sampled_and_run_valid():
    """The scheduler's admission samples the first token too, and a sampled
    run still completes with every token in-vocab."""
    from repro.serve.scheduler import SlotPoolEngine
    cfg, model, params = _setup()
    reqs = _requests(cfg, 3, np.random.default_rng(6), max_new=(4, 7))
    scfg = ServeConfig(max_len=32, cache_dtype="float32",
                       scheduler="continuous", n_slots=2, decode_burst=4,
                       temperature=50.0)
    firsts = set()
    for s in range(8):
        eng = SlotPoolEngine(model, params, scfg, key=jax.random.PRNGKey(s))
        done = eng.run(reqs[:1])
        firsts.add(done[0].tokens[0])
    assert len(firsts) > 1, "scheduler first token still greedy"
    eng = SlotPoolEngine(model, params, scfg, key=jax.random.PRNGKey(0))
    done = eng.run(reqs)
    for r in reqs:
        toks = np.array(done[r.rid].tokens)
        assert toks.shape[0] == r.max_new
        assert np.all((toks >= 0) & (toks < cfg.vocab))
