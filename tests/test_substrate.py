"""Substrate tests: data determinism, optimizers, checkpointing, fault
tolerance, gradient accumulation, compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.data.synthetic import DataConfig, classify_batch, lm_batch

F32 = jnp.float32


class TestData:
    def test_deterministic(self):
        cfg = DataConfig(vocab=97, seq_len=16, global_batch=4)
        a, b = lm_batch(cfg, 7), lm_batch(cfg, 7)
        np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                      np.asarray(b["tokens"]))

    def test_steps_differ(self):
        cfg = DataConfig(vocab=97, seq_len=16, global_batch=4)
        a, b = lm_batch(cfg, 1), lm_batch(cfg, 2)
        assert not np.array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))

    def test_host_shards_disjoint_and_sized(self):
        cfg0 = DataConfig(vocab=97, seq_len=8, global_batch=8, n_hosts=2,
                          host_id=0)
        cfg1 = DataConfig(vocab=97, seq_len=8, global_batch=8, n_hosts=2,
                          host_id=1)
        a, b = lm_batch(cfg0, 3), lm_batch(cfg1, 3)
        assert a["tokens"].shape == (4, 8)
        assert not np.array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))

    def test_targets_shifted(self):
        cfg = DataConfig(vocab=97, seq_len=16, global_batch=2)
        d = lm_batch(cfg, 0)
        np.testing.assert_array_equal(np.asarray(d["tokens"][:, 1:]),
                                      np.asarray(d["targets"][:, :-1]))

    def test_classify_markers(self):
        d = classify_batch(0, 0, 32, 24, vocab=64)
        toks, labels = np.asarray(d["tokens"]), np.asarray(d["labels"])
        for i in range(32):
            counts = [np.sum(toks[i] == c + 1) for c in range(4)]
            assert int(np.argmax(counts)) == labels[i]


class TestOptim:
    def _quad(self, opt_name):
        target = jnp.array([1.0, -2.0, 3.0])
        params = {"w": jnp.zeros(3)}
        cfg = optim.OptConfig(name=opt_name, lr=0.1, weight_decay=0.0)
        st = optim.init(cfg, params)
        for _ in range(200):
            g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
            params, st = optim.update(cfg, g, st, params)
        return float(jnp.abs(params["w"] - target).max())

    @pytest.mark.parametrize("name", ["adamw", "sgd", "adafactor"])
    def test_converges_on_quadratic(self, name):
        assert self._quad(name) < 0.15

    def test_adamw_master_weights_bf16_params(self):
        params = {"w": jnp.ones(4, jnp.bfloat16)}
        cfg = optim.OptConfig(name="adamw", lr=1e-3)
        st = optim.init(cfg, params)
        assert st["master"]["w"].dtype == jnp.float32
        g = {"w": jnp.full(4, 1e-4, F32)}
        p2, st2 = optim.update(cfg, g, st, params)
        assert p2["w"].dtype == jnp.bfloat16
        # master accumulates below bf16 resolution
        assert float(jnp.abs(st2["master"]["w"] - 1).max()) > 0

    def test_adafactor_memory_factored(self):
        params = {"w": jnp.ones((64, 32))}
        st = optim.init(optim.OptConfig(name="adafactor"), params)
        assert st["vr"]["w"].shape == (64,)
        assert st["vc"]["w"].shape == (32,)

    def test_clip_by_global_norm(self):
        tree = {"a": jnp.full(4, 10.0)}
        clipped, gn = optim.clip_by_global_norm(tree, 1.0)
        assert abs(float(optim.global_norm(clipped)) - 1.0) < 1e-5
        assert float(gn) == 20.0


class TestGradAccum:
    def test_microbatch_equivalent(self):
        from repro.configs import get_config, smoke_config
        from repro.configs.base import TrainConfig
        from repro.configs.shapes import ShapeSpec, concrete_batch
        from repro.models import build_model
        from repro.models.layers import unbox
        from repro.train.step import make_step_fn
        from repro.train.state import init_state

        cfg = smoke_config(get_config("olmo-1b")).with_(
            softmax_impl="exact", compute_dtype="float32")
        model = build_model(cfg)
        ocfg = optim.OptConfig(name="sgd", lr=1e-2, weight_decay=0.0)
        batch = concrete_batch(cfg, ShapeSpec("t", "train", 16, 8))

        outs = []
        for mb in (0, 2):
            tcfg = TrainConfig(microbatch=mb, grad_clip=1e9, z_loss=0.0)
            state = init_state(model, ocfg, jax.random.PRNGKey(0))
            step = make_step_fn(model, tcfg, ocfg)
            state2, metrics = jax.jit(step)(state, batch)
            outs.append((metrics["loss"], state2["params"]))
        np.testing.assert_allclose(float(outs[0][0]), float(outs[1][0]),
                                   rtol=2e-5)
        for a, b in zip(jax.tree.leaves(outs[0][1]), jax.tree.leaves(outs[1][1])):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=2e-5, rtol=2e-4)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        from repro.checkpoint import checkpointer as ck
        state = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4, jnp.bfloat16)},
                 "step": jnp.int32(5)}
        ck.save(str(tmp_path), 5, state)
        like = jax.eval_shape(lambda: state)
        restored, step = ck.restore(str(tmp_path), 5, like)
        assert step == 5
        for x, y in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(x, np.float32),
                                          np.asarray(y, np.float32))
            assert x.dtype == y.dtype

    def test_keep_k_gc(self, tmp_path):
        from repro.checkpoint import checkpointer as ck
        for s in range(6):
            ck.save(str(tmp_path), s, {"x": jnp.ones(2)}, keep=2)
        assert ck.all_steps(str(tmp_path)) == [4, 5]

    def test_no_partial_checkpoint_visible(self, tmp_path):
        from repro.checkpoint import checkpointer as ck
        os.makedirs(tmp_path / "step_00000009.tmp")  # simulated crash debris
        ck.save(str(tmp_path), 3, {"x": jnp.ones(2)})
        assert ck.all_steps(str(tmp_path)) == [3]
        assert ck.latest_step(str(tmp_path)) == 3


class TestFaultTolerance:
    def test_restart_manager_resumes(self, tmp_path):
        from repro.checkpoint import checkpointer as ck
        from repro.distributed.fault_tolerance import RestartManager
        calls = []

        def body(start):
            calls.append(start)
            state = {"x": jnp.full(2, float(start))}
            for step in range(start, 10):
                state = {"x": state["x"] + 1}
                if step == 4 and len(calls) == 1:
                    ck.save(str(tmp_path), step + 1, state)
                    raise RuntimeError("injected node failure")
            return 10

        rm = RestartManager(str(tmp_path), max_restarts=2)
        assert rm.run(body) == 10
        assert calls == [0, 5]  # resumed from the checkpointed step

    def test_restart_bounded(self, tmp_path):
        from repro.distributed.fault_tolerance import RestartManager

        def body(start):
            raise RuntimeError("always fails")

        with pytest.raises(RuntimeError):
            RestartManager(str(tmp_path), max_restarts=2).run(body)

    def test_straggler_monitor(self):
        from repro.distributed.fault_tolerance import StragglerMonitor
        m = StragglerMonitor(threshold=3.0, warm=3)
        for _ in range(10):
            m.observe(0.1)
        assert m.flagged == 0
        assert m.observe(1.0) is True
        assert m.flagged == 1
        # outlier did not poison the EMA
        assert m.ema < 0.2

    def test_elastic_remesh_shrinks_data_axis(self):
        from repro.distributed.fault_tolerance import elastic_remesh
        mesh = elastic_remesh(model_size=1)
        assert mesh.shape["model"] == 1
        assert mesh.shape["data"] >= 1


class TestCompression:
    def test_int8_quantize_bounded_error(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (256,)) * 3
        from repro.optim.compression import dequantize_int8, quantize_int8
        q, s = quantize_int8(x, jax.random.PRNGKey(1))
        err = jnp.abs(dequantize_int8(q, s) - x)
        assert float(err.max()) <= float(s) + 1e-6

    def test_int8_stochastic_unbiased(self):
        x = jnp.full((8,), 0.3)
        from repro.optim.compression import dequantize_int8, quantize_int8
        vals = []
        for i in range(300):
            q, s = quantize_int8(x, jax.random.PRNGKey(i))
            vals.append(np.asarray(dequantize_int8(q, s)))
        assert abs(np.mean(vals) - 0.3) < 2e-3

    def test_compressed_psum_tree_axis1(self):
        from functools import partial
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.distributed.compat import shard_map
        from repro.optim.compression import compressed_psum_tree
        mesh = Mesh(np.array(jax.devices()[:1]), ("dp",))
        tree = {"g": jnp.linspace(-1, 1, 16)}

        @partial(shard_map, mesh=mesh, in_specs=P(), out_specs=P())
        def f(t):
            return compressed_psum_tree(t, "dp", jax.random.PRNGKey(0))
        out = f(tree)
        np.testing.assert_allclose(np.asarray(out["g"]),
                                   np.asarray(tree["g"]), atol=0.02)
