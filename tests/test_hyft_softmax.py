"""Hyft softmax emulation: forward/backward behaviour + properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests degrade gracefully
from hypothesis import given, settings, strategies as st

from repro.core.hyft import (HYFT16, HYFT16B, HYFT32, HyftConfig, hyft_jacobian,
                             hyft_softmax, hyft_softmax_bwd, hyft_softmax_fwd)

F32 = jnp.float32
KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("cfg", [HYFT16, HYFT32, HYFT16B], ids=lambda c: c.io_dtype)
class TestForward:
    def test_close_to_exact(self, cfg):
        z = jax.random.normal(KEY, (32, 128), F32) * 3
        s = hyft_softmax_fwd(z, cfg).astype(F32)
        ref = jax.nn.softmax(z, -1)
        assert float(jnp.mean(jnp.abs(s - ref))) < 2e-3
        # worst-case per-element error bounded by the double-Taylor chain
        assert float(jnp.max(jnp.abs(s - ref))) < 0.13

    def test_output_range_and_sum(self, cfg):
        z = jax.random.normal(KEY, (64, 64), F32) * 5
        s = hyft_softmax_fwd(z, cfg).astype(F32)
        assert float(s.min()) >= 0.0
        assert float(s.max()) <= 1.0 + 1e-3
        sums = jnp.sum(s, -1)
        assert float(jnp.abs(sums - 1).max()) < 0.15  # approx-normalized

    def test_io_dtype(self, cfg):
        z = jax.random.normal(KEY, (4, 16), F32)
        assert hyft_softmax_fwd(z, cfg).dtype == cfg.dtype

    def test_masked_positions_negligible(self, cfg):
        # the numerator bypasses the adder-tree quantization (paper Fig. 2),
        # so a masked entry is <= 2^-45-ish, not an exact zero in wide-
        # exponent output formats (bf16/f32); f16 flushes it to 0
        z = jnp.array([[1.0, -1e9, 2.0, -1e9]], F32)
        s = hyft_softmax_fwd(z, cfg).astype(F32)
        assert float(s[0, 1]) < 1e-9 and float(s[0, 3]) < 1e-9

    def test_uniform_input(self, cfg):
        s = hyft_softmax_fwd(jnp.zeros((2, 8), F32), cfg).astype(F32)
        np.testing.assert_allclose(np.asarray(s), 0.125, atol=1e-3)

    def test_shift_invariance_on_grid(self, cfg):
        # shifting by an exactly-representable constant leaves d_raw intact
        z = jax.random.normal(KEY, (8, 32), F32)
        c = 2.0 ** -cfg.frac_bits * 64
        a = hyft_softmax_fwd(z, cfg)
        b = hyft_softmax_fwd(z + c, cfg)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestStep:
    def test_step_changes_only_max_search(self):
        z = jax.random.normal(KEY, (16, 64), F32) * 2
        exact = jax.nn.softmax(z, -1)
        for step in (1, 2, 4):
            cfg = dataclasses.replace(HYFT32, step=step)
            s = hyft_softmax_fwd(z, cfg).astype(F32)
            # degrades gracefully with the stride (paper §3.1)
            assert float(jnp.abs(s - exact).mean()) < 0.004 * step + 0.002

    def test_step_missed_max_saturates(self):
        # put the max at an odd index so step=2 misses it; outputs stay finite
        z = jnp.zeros((1, 8), F32).at[0, 3].set(10.0)
        cfg = dataclasses.replace(HYFT16, step=2)
        s = hyft_softmax_fwd(z, cfg).astype(F32)
        assert bool(jnp.all(jnp.isfinite(s)))
        assert float(s[0, 3]) == float(jnp.max(s))


class TestBackward:
    def test_bwd_close_to_exact_vjp(self):
        z = jax.random.normal(KEY, (8, 64), F32) * 2
        s = jax.nn.softmax(z, -1)
        dy = jax.random.normal(jax.random.PRNGKey(1), (8, 64), F32)
        dz = hyft_softmax_bwd(s, dy, HYFT32).astype(F32)
        ref = s * (dy - jnp.sum(dy * s, -1, keepdims=True))
        assert float(jnp.abs(dz - ref).max()) < 5e-3

    def test_custom_vjp_dtype_matches_primal(self):
        z = jax.random.normal(KEY, (4, 16), F32)
        g = jax.grad(lambda x: hyft_softmax(x, HYFT16).astype(F32).sum())(z)
        assert g.dtype == z.dtype

    def test_grad_modes(self):
        z = jax.random.normal(KEY, (4, 32), F32)
        w = jax.random.normal(jax.random.PRNGKey(2), (32,))
        ge = jax.grad(lambda x: jnp.sum(
            hyft_softmax(x, dataclasses.replace(HYFT32, grad="exact")) * w))(z)
        gh = jax.grad(lambda x: jnp.sum(hyft_softmax(x, HYFT32) * w))(z)
        gt = jax.grad(lambda x: jnp.sum(jax.nn.softmax(x, -1) * w))(z)
        # both approximate the true grad; hyft-grad within a few % extra
        assert float(jnp.abs(ge - gt).max()) < 0.05
        assert float(jnp.abs(gh - gt).max()) < 0.06

    def test_jacobian_structure(self):
        s = jax.nn.softmax(jax.random.normal(KEY, (1, 6)), -1)
        J = hyft_jacobian(s, HYFT32)[0].astype(F32)
        s0 = np.asarray(s[0], np.float32)
        ref = np.diag(s0) - np.outer(s0, s0)
        np.testing.assert_allclose(np.asarray(J), ref, atol=5e-3)


@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 6), st.integers(4, 100))
@settings(max_examples=25, deadline=None)
def test_property_valid_distribution(seed, rows, cols):
    z = jax.random.normal(jax.random.PRNGKey(seed), (rows, cols), F32) * 4
    s = hyft_softmax_fwd(z, HYFT16).astype(F32)
    assert bool(jnp.all(jnp.isfinite(s)))
    assert float(s.min()) >= 0.0
    assert float(jnp.abs(jnp.sum(s, -1) - 1).max()) < 0.2


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=25, deadline=None)
def test_property_argmax_preserved(seed):
    """The paper's core accuracy claim: the attention *ordering* survives."""
    z = jax.random.normal(jax.random.PRNGKey(seed), (8, 32), F32) * 3
    s = hyft_softmax_fwd(z, HYFT16).astype(F32)
    assert bool(jnp.all(jnp.argmax(s, -1) == jnp.argmax(z, -1)))
