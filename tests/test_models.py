"""Per-arch smoke tests (reduced configs) + decode/prefill consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config, smoke_config
from repro.configs.shapes import ShapeSpec, concrete_batch
from repro.models import build_model
from repro.models.layers import unbox

F32 = jnp.float32
TINY = ShapeSpec("tiny", "train", 32, 2)


def _setup(name, **overrides):
    overrides.setdefault("softmax_impl", "hyft16")
    cfg = smoke_config(get_config(name)).with_(**overrides)
    model = build_model(cfg)
    params = unbox(model.init(jax.random.PRNGKey(0)))
    return cfg, model, params


@pytest.mark.parametrize("name", ASSIGNED)
def test_train_step_finite(name):
    cfg, model, params = _setup(name)
    batch = concrete_batch(cfg, TINY)
    loss, metrics = model.loss(params, batch, remat="full")
    assert jnp.isfinite(loss), name
    g = jax.grad(lambda p: model.loss(p, batch, remat="full")[0])(params)
    gn = sum(jnp.sum(x.astype(F32) ** 2) for x in jax.tree.leaves(g))
    assert jnp.isfinite(gn), name
    assert float(gn) > 0, f"{name}: gradient is identically zero"


@pytest.mark.parametrize("name", ASSIGNED)
def test_output_shapes(name):
    cfg, model, params = _setup(name)
    batch = concrete_batch(cfg, TINY)
    if cfg.family == "encdec":
        from repro.models import encdec
        mem = encdec.encode(params, batch["frames"], cfg, remat="none")
        assert mem.shape == (2, cfg.frontend_len, cfg.d_model)
        hid = encdec.decode_train(params, batch["tokens"], mem, cfg, remat="none")
        assert hid.shape == (2, 32, cfg.d_model)
    else:
        from repro.models import transformer
        hid, aux = transformer.forward(params, batch["tokens"], cfg,
                                       embeds_prefix=batch.get("embeds"),
                                       remat="none")
        # vlm batches carry (32 - frontend_len) text tokens + the prefix
        assert hid.shape == (2, 32, cfg.d_model)
        logits = transformer.logits_fn(params, hid, cfg)
        assert logits.shape[-1] == cfg.vocab
        assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", ["qwen2-1.5b", "mamba2-370m", "zamba2-7b",
                                  "whisper-medium", "phi3.5-moe-42b-a6.6b"])
def test_decode_step_runs(name):
    cfg, model, params = _setup(name)
    B, max_len = 2, 16
    cache = model.init_cache(params, B, max_len, jnp.float32)
    tok = jnp.ones((B, 1), jnp.int32)
    logits, cache2 = model.decode_step(params, cache, tok, 0, )
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    logits3, _ = model.decode_step(params, cache2, tok, 1)
    assert bool(jnp.all(jnp.isfinite(logits3)))


def test_decode_matches_teacher_forced_dense():
    """Greedy decode logits == forward logits at the same positions."""
    cfg, model, params = _setup("qwen2-1.5b", softmax_impl="exact",
                                compute_dtype="float32")
    from repro.models import transformer
    B, S = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab)
    hid, _ = transformer.forward(params, toks, cfg, remat="none")
    full_logits = transformer.logits_fn(params, hid, cfg)

    cache = model.init_cache(params, B, S, jnp.float32)
    step_logits = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache, toks[:, t:t + 1], t)
        step_logits.append(lg[:, 0])
    step_logits = jnp.stack(step_logits, 1)
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(full_logits), atol=2e-3, rtol=2e-3)


def test_ssm_decode_matches_train():
    """SSD chunked train path == sequential decode recurrence."""
    cfg, model, params = _setup("mamba2-370m", compute_dtype="float32")
    from repro.models import transformer
    B, S = 1, 16
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, S), 0, cfg.vocab)
    hid, _ = transformer.forward(params, toks, cfg, remat="none")
    full_logits = transformer.logits_fn(params, hid, cfg)

    cache = model.init_cache(params, B, S, jnp.float32)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache, toks[:, t:t + 1], t)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full_logits),
                               atol=2e-2, rtol=2e-2)


def test_hybrid_shared_attn_fires():
    """zamba2: layers with flag apply the shared block -> different output
    than pure-ssm stack."""
    cfg, model, params = _setup("zamba2-7b", compute_dtype="float32")
    from repro.models import transformer
    toks = jax.random.randint(jax.random.PRNGKey(7), (1, 8), 0, cfg.vocab)
    hid, _ = transformer.forward(params, toks, cfg, remat="none")
    # zero out the shared attention -> output must change
    import copy
    p2 = jax.tree.map(lambda x: x, params)
    p2["shared_attn"] = jax.tree.map(jnp.zeros_like, params["shared_attn"])
    hid2, _ = transformer.forward(p2, toks, cfg, remat="none")
    assert float(jnp.abs(hid - hid2).max()) > 1e-4


def test_vlm_prefix_changes_output():
    cfg, model, params = _setup("internvl2-1b", compute_dtype="float32")
    batch = concrete_batch(cfg, TINY)
    l1, _ = model.loss(params, batch, remat="none")
    batch2 = dict(batch, embeds=batch["embeds"] + 1.0)
    l2, _ = model.loss(params, batch2, remat="none")
    assert float(l1) != float(l2)


def test_moe_capacity_drops_overflow():
    """With capacity_factor -> 0 almost all tokens are dropped: output ~ 0."""
    cfg, model, params = _setup("phi3.5-moe-42b-a6.6b")
    from repro.models.moe import moe_apply
    lp = jax.tree.map(lambda x: x[0], params["blocks"]["moe"])
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 16, cfg.d_model))
    y_full, _ = moe_apply(lp, x, cfg)
    y_min, _ = moe_apply(lp, x, cfg.with_(capacity_factor=1e-9))
    nz = lambda y: int(jnp.sum(jnp.any(jnp.abs(y) > 0, -1)))
    # capacity floor is 1 slot/expert: at most E*k tokens survive
    assert nz(y_min) <= cfg.n_experts * cfg.moe_top_k
    assert nz(y_full) > nz(y_min)


@pytest.mark.parametrize("name", ["mamba2-370m", "zamba2-7b", "whisper-medium"])
def test_parallel_prefill_matches_sequential(name):
    """The §Perf prefill lever is numerics-preserving: the one-pass chunked
    fill produces the same logits and a decode-equivalent cache as the
    baseline token-by-token scan."""
    cfg, model_seq, params = _setup(name, compute_dtype="float32",
                                    softmax_impl="exact")
    from repro.models import build_model
    model_par = build_model(cfg.with_(parallel_prefill=True))
    S = 16  # multiple of the smoke ssm_chunk
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, S), 0,
                                          cfg.vocab, jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (2, cfg.frontend_len, cfg.frontend_dim))
    c1 = model_seq.init_cache(params, 2, S + 4, jnp.float32)
    l1, cache1, _ = model_seq.prefill(params, c1, batch)
    c2 = model_par.init_cache(params, 2, S + 4, jnp.float32)
    l2, cache2, _ = model_par.prefill(params, c2, batch)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               atol=2e-4, rtol=2e-4)
    tok = jnp.argmax(l2.reshape(2, -1), -1)[:, None].astype(jnp.int32)
    d1, _ = model_seq.decode_step(params, cache1, tok, S)
    d2, _ = model_par.decode_step(params, cache2, tok, S)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                               atol=2e-4, rtol=2e-4)


def test_hybrid_shared_cache_per_invocation():
    """Each shared-attention invocation owns a KV cache slice (stacked on a
    leading invocation axis) — invocations must not overwrite each other."""
    from repro.models.transformer import hybrid_n_invocations
    cfg, model, params = _setup("zamba2-7b", compute_dtype="float32")
    ninv = hybrid_n_invocations(cfg)
    assert ninv == cfg.n_layers // cfg.attn_every
    cache = model.init_cache(params, 2, 8, jnp.float32)
    assert cache["shared_attn"]["k"].shape[0] == ninv
    tok = jnp.ones((2, 1), jnp.int32)
    _, c2 = model.decode_step(params, cache, tok, 0)
    k = np.asarray(c2["shared_attn"]["k"][:, :, :, 0])  # written position
    # every invocation wrote its own (distinct) K at position 0
    assert ninv >= 2
    assert not np.allclose(k[0], k[1])
