"""Unit + property tests for the bit-level numeric primitives."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests degrade gracefully
from hypothesis import given, settings, strategies as st

from repro.core import numerics as nm

F32 = jnp.float32


class TestFp2Fx:
    def test_roundtrip_within_half_ulp(self):
        x = jnp.linspace(-7.9, 7.9, 1001, dtype=F32)
        raw = nm.fp2fx(x, frac_bits=10, total_bits=16)
        back = nm.fx2fp(raw, 10)
        assert float(jnp.max(jnp.abs(back - x))) <= 0.5 * 2.0 ** -10 + 1e-7

    def test_saturation(self):
        x = jnp.array([1e9, -1e9, jnp.inf, -jnp.inf], F32)
        raw = nm.fp2fx(x, 10, 16)
        assert int(raw[0]) == 2 ** 15 - 1
        assert int(raw[1]) == -(2 ** 15)
        assert int(raw[2]) == 2 ** 15 - 1
        assert int(raw[3]) == -(2 ** 15)

    @given(st.floats(-30, 30), st.integers(6, 20))
    @settings(max_examples=50, deadline=None)
    def test_quantization_grid(self, x, f):
        raw = nm.fp2fx(jnp.float32(x), f, 24)
        want = min(max(round(x * 2 ** f), -(2 ** 23)), 2 ** 23 - 1)
        # round-to-nearest on the grid (fp32 scaling is exact below 2^24;
        # allow 2 ulp near the exactness boundary)
        assert abs(int(raw) - want) <= max(2, abs(want) * 2 ** -22)


class TestPow2Float:
    def test_exact_powers(self):
        k = jnp.arange(-126, 128, dtype=jnp.int32)
        got = nm.pow2_float(k)
        want = 2.0 ** k.astype(F32)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_flush_to_zero(self):
        assert float(nm.pow2_float(jnp.int32(-127))) == 0.0
        assert float(nm.pow2_float(jnp.int32(-300))) == 0.0


class TestExpUnit:
    def test_matches_exp_within_taylor_bound(self):
        d = jnp.linspace(-8, 0, 801, dtype=F32)
        raw = nm.fp2fx(d, 16, 24)
        e, m = nm.exp_unit(raw, 16, 16)
        val = (1.0 + m.astype(F32) / 2 ** 16) * nm.pow2_float(e)
        rel = jnp.abs(val - jnp.exp(d)) / jnp.exp(d)
        # compound worst case on [-8,0]: Taylor 2^u(1+v/2) (~6.2% at
        # v~-0.57) x Booth log2e drift (2^(0.0052|d|), ~2.9% at d=-8) ~ 9.3%;
        # far tail drifts more relatively but is absolutely negligible
        assert float(jnp.max(rel)) < 0.095

    def test_far_tail_absolutely_negligible(self):
        d = jnp.linspace(-30, -8, 401, dtype=F32)
        raw = nm.fp2fx(d, 16, 24)
        e, m = nm.exp_unit(raw, 16, 16)
        val = (1.0 + m.astype(F32) / 2 ** 16) * nm.pow2_float(e)
        assert float(jnp.max(jnp.abs(val - jnp.exp(d)))) < 1e-4

    def test_exp_zero_is_one(self):
        e, m = nm.exp_unit(jnp.zeros((1,), jnp.int32), 16, 16)
        assert int(e[0]) == 0 and int(m[0]) == 0

    def test_saturates_positive_input(self):
        # strided max can leave d > 0; unit must clamp, not wrap
        raw = nm.fp2fx(jnp.array([3.0], F32), 16, 24)
        e, m = nm.exp_unit(raw, 16, 16)
        val = (1.0 + m.astype(F32) / 2 ** 16) * nm.pow2_float(e)
        assert float(val[0]) == 1.0


class TestLogDivMul:
    @given(st.floats(0.01, 100.0), st.floats(0.01, 100.0))
    @settings(max_examples=80, deadline=None)
    def test_log_div_relative_bound(self, a, b):
        _, ea, ma = nm.float_fields(jnp.float32(a), 16)
        _, eb, mb = nm.float_fields(jnp.float32(b), 16)
        got = float(nm.log_div(ea, ma, eb, mb, 16))
        # double Taylor: log2(1+x)~x both ways -> <= ~12.6% relative
        assert abs(got - a / b) / (a / b) < 0.13

    def test_log_div_exact_for_powers_of_two(self):
        for a, b in [(4.0, 2.0), (1.0, 8.0), (0.5, 0.25)]:
            _, ea, ma = nm.float_fields(jnp.float32(a), 16)
            _, eb, mb = nm.float_fields(jnp.float32(b), 16)
            assert float(nm.log_div(ea, ma, eb, mb, 16)) == a / b

    @given(st.floats(-50, 50), st.floats(-50, 50))
    @settings(max_examples=80, deadline=None)
    def test_log_mul_relative_bound(self, a, b):
        if abs(a) < 1e-3 or abs(b) < 1e-3:
            return
        got = float(nm.log_mul(jnp.float32(a), jnp.float32(b), 16))
        # half-range mantissa truncation: <= 2^-8 relative on top of exact
        assert abs(got - a * b) / abs(a * b) < 0.005

    def test_log_mul_signs_and_zero(self):
        assert float(nm.log_mul(jnp.float32(-2.0), jnp.float32(3.0), 16)) < 0
        assert float(nm.log_mul(jnp.float32(-2.0), jnp.float32(-3.0), 16)) > 0
        assert float(nm.log_mul(jnp.float32(0.0), jnp.float32(3.0), 16)) == 0.0


class TestAdderTree:
    def test_fx_quantize_truncates_toward_neg_inf(self):
        x = jnp.array([1.2345, -1.2345], F32)
        q = nm.fx_quantize(x, 8)
        assert float(q[0]) == np.floor(1.2345 * 256) / 256
        assert float(q[1]) == np.floor(-1.2345 * 256) / 256

    def test_expfloat_to_fx_exact_grid(self):
        e = jnp.array([-1, -3], jnp.int32)
        m = jnp.array([0, 1 << 15], jnp.int32)  # 1.0 -> 0.5 ; 1.5 -> 0.1875
        q = nm.expfloat_to_fx(e, m, 16, 14)
        assert float(q[0]) == 0.5
        assert float(q[1]) == 1.5 / 8

    def test_lod_refloat_truncation(self):
        s = jnp.float32(5.75)  # 2^2 * 1.4375
        e, m = nm.lod_refloat(s, 4)
        assert int(e) == 2
        assert int(m) == int(0.4375 * 16)
