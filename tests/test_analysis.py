"""Each analysis pass must catch its seeded violation and pass the repo.

The seeded fixtures are traced/parsed only — never executed — so a broken
index map or a smuggled convert costs a trace, not a crash.
"""
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import lint
from repro.analysis import jaxpr_audit
from repro.analysis import pallas_check
from repro.analysis.common import Finding
from repro.analysis.retrace import RetraceError, RetraceGuard, serve_steady_state

F32 = jnp.float32


@pytest.fixture(autouse=True, scope="module")
def _isolate_executable_caches():
    """The audit/retrace passes build real serving executables; restore the
    process-global FIFO caches afterwards so this module doesn't push later
    tests' entries toward the eviction cap."""
    from repro.serve import engine, scheduler, spec
    stores = [engine._PREFILL_CACHE, engine._STEP_CACHE, engine._LOOP_CACHE,
              engine._CHUNK_CACHE, scheduler._BURST_CACHE,
              scheduler._SCATTER_CACHE, scheduler._AXES_CACHE,
              scheduler._ENCODE_CACHE, spec._DRAFT_LOOP_CACHE,
              spec._SPEC_CACHE]
    snaps = [dict(s) for s in stores]
    yield
    for store, snap in zip(stores, snaps):
        store.clear()
        store.update(snap)


# -- jaxpr format-flow auditor ----------------------------------------------


def test_jaxpr_catches_weak_promotion():
    # jnp.where(x < 0, -1, 0) builds a weak-typed rank-1 i32 that the add
    # then promotes to f32 — the exact bug fixed in numerics.log_div
    def bad(x):
        return x + jnp.where(x < 0, -1, 0)

    closed = jax.make_jaxpr(bad)(jnp.zeros(8, F32))
    rules = {f.rule for f in jaxpr_audit.audit_jaxpr(closed, "seeded")}
    assert "format.weak-promotion" in rules


def test_jaxpr_catches_undeclared_convert():
    # int8 -> float16 is not a declared boundary (DESIGN.md #14)
    def bad(x):
        return x.astype(jnp.float16) * jnp.float16(2)

    closed = jax.make_jaxpr(bad)(jnp.zeros((4, 4), jnp.int8))
    rules = {f.rule for f in jaxpr_audit.audit_jaxpr(closed, "seeded")}
    assert "format.undeclared-convert" in rules


def test_jaxpr_scalar_weak_convert_is_note_not_finding():
    def ok(x):
        # rank-0 weak i32 -> f32 convert: churn, folded by XLA
        return jnp.where(x.sum() > 0, 1, 0) * x

    closed = jax.make_jaxpr(ok)(jnp.zeros(8, F32))
    stats = {}
    assert jaxpr_audit.audit_jaxpr(closed, "ok", stats=stats) == []
    assert stats.get("scalar_weak_converts", 0) >= 1


def test_jaxpr_donation_check():
    def step(params, cache):
        return {"k": cache["k"] + params}

    args = (jnp.ones(4), {"k": jnp.zeros(4)})
    bad = jax.jit(step)
    good = jax.jit(step, donate_argnums=(1,))
    assert any(f.rule == "donation.cache-not-donated"
               for f in jaxpr_audit.audit_donation(bad, args, 1, "bad"))
    assert jaxpr_audit.audit_donation(good, args, 1, "good") == []


@pytest.mark.slow
def test_jaxpr_repo_clean():
    assert jaxpr_audit.run() == []


# -- Pallas tile checker -----------------------------------------------------


def _toy_kernel_entry(index_map):
    from jax.experimental import pallas as pl

    def kern(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    def make():
        x = jnp.zeros((8, 16), F32)
        fn = pl.pallas_call(
            kern, grid=(4,),
            in_specs=[pl.BlockSpec((2, 16), index_map)],
            out_specs=pl.BlockSpec((2, 16), index_map),
            out_shape=jax.ShapeDtypeStruct((8, 16), F32),
            interpret=True)
        return fn, (x,)
    return pallas_check.KernelEntry("toy", make)


def test_pallas_catches_out_of_bounds_index_map():
    # block row i+1 of 4 runs off the 8-row array at the last grid point;
    # the checker proves it by evaluating the map over the whole grid —
    # the kernel itself is never run
    entry = _toy_kernel_entry(lambda i: (i + 1, 0))
    assert any(f.rule == "tile.out-of-bounds"
               for f in pallas_check.check_entry(entry))


def test_pallas_clean_index_map_passes():
    entry = _toy_kernel_entry(lambda i: (i, 0))
    assert pallas_check.check_entry(entry) == []


def test_pallas_catches_unaligned_block():
    from jax.experimental import pallas as pl

    def kern(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    def make():
        x = jnp.zeros((10, 16), F32)  # 10 % 3 != 0
        fn = pl.pallas_call(
            kern, grid=(4,),
            in_specs=[pl.BlockSpec((3, 16), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((3, 16), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((10, 16), F32),
            interpret=True)
        return fn, (x,)

    entry = pallas_check.KernelEntry("unaligned", make)
    assert any(f.rule == "tile.unaligned"
               for f in pallas_check.check_entry(entry))


def test_pallas_catches_bad_ref_dtype():
    entry = _toy_kernel_entry(lambda i: (i, 0))
    entry = pallas_check.KernelEntry(
        "toy", entry.make, expect_dtypes={0: "int8"})
    assert any(f.rule == "tile.bad-dtype"
               for f in pallas_check.check_entry(entry))


@pytest.mark.slow
def test_pallas_repo_registry_clean():
    assert pallas_check.run() == []


# -- retrace guard -----------------------------------------------------------


def test_retrace_guard_catches_fresh_compile():
    f = jax.jit(lambda x: x * 2 + 1)
    with pytest.raises(RetraceError, match="compilation"):
        with RetraceGuard():
            f(jnp.zeros(7))  # never-seen shape: must compile


def test_retrace_guard_warm_call_is_clean():
    f = jax.jit(lambda x: x * 3 - 1)
    x = jnp.zeros(5)
    f(x)  # cold call outside the guard
    with RetraceGuard() as g:
        f(x)
    assert g.compiles == []


def test_retrace_guard_budget_and_restore():
    prev = jax.config.jax_log_compiles
    f = jax.jit(lambda x: x - 4)
    x = jnp.zeros(11)  # built outside: jnp.zeros itself compiles
    with RetraceGuard(max_compiles=1) as g:
        f(x)
    assert len(g.compiles) == 1
    assert jax.config.jax_log_compiles == prev


@pytest.mark.slow
def test_retrace_steady_state_serving():
    # 8 admissions through warm buckets + decode bursts compile nothing new
    guard = serve_steady_state("continuous", n_requests=8)
    assert guard.compiles == []


# -- repo lint ---------------------------------------------------------------


_SEEDED = textwrap.dedent("""
    import functools
    import numpy as np
    import jax
    import jax.numpy as jnp

    @jax.jit
    def bad_branch(x):
        if x > 0:              # traced-bool
            return x
        return -x

    @jax.jit
    def bad_host(x):
        y = float(x)           # host-call
        return np.tanh(x) + y  # host-call (np. on traced)

    @jax.jit
    def bad_seed(x):
        k = jax.random.PRNGKey(0)  # prng.constant-seed
        return x + jax.random.normal(k, x.shape)

    @functools.partial(jax.jit, static_argnames=("n",))
    def bad_cache_step(params, cache, n):   # cache.not-donated
        return cache
""").strip()


def _lint_snippet(src: str):
    import tempfile, pathlib
    with tempfile.TemporaryDirectory() as d:
        p = pathlib.Path(d) / "snippet.py"
        p.write_text(src)
        return lint.run(roots=[pathlib.Path(d)])


def test_lint_catches_all_seeded_rules():
    rules = {f.rule for f in _lint_snippet(_SEEDED)}
    assert {"traced-bool", "host-call",
            "prng.constant-seed", "cache.not-donated"} <= rules


def test_lint_static_arg_branch_is_allowed():
    ok = textwrap.dedent("""
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("flag",))
        def f(x, flag):
            if flag:           # static: not traced
                return x
            return -x
    """).strip()
    assert [f for f in _lint_snippet(ok) if f.rule == "traced-bool"] == []


def test_lint_waiver_comment():
    waived = textwrap.dedent("""
        import jax

        @jax.jit
        def f(x):
            if x > 0:  # lint: allow(traced-bool)
                return x
            return -x
    """).strip()
    assert [f for f in _lint_snippet(waived) if f.rule == "traced-bool"] == []


def test_lint_repo_clean():
    assert lint.run() == []


def test_finding_str():
    f = Finding("lint", "traced-bool", "a.py:3", "boom")
    assert str(f) == "[lint.traced-bool] a.py:3 -- boom"
