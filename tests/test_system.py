"""End-to-end behaviour: tiny training runs, restart equivalence, serving,
baselines, and the distributed-softmax (sequence-parallel) combine."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import get_config, smoke_config
from repro.configs.base import TrainConfig
from repro.data.synthetic import DataConfig, lm_batch
from repro.models import build_model
from repro.train.loop import run_train
from repro.train.state import init_state
from repro.train.step import make_step_fn

F32 = jnp.float32


def _tiny_setup(softmax="hyft16", arch="olmo-1b", steps=30, vocab=64):
    cfg = smoke_config(get_config(arch)).with_(
        softmax_impl=softmax, vocab=vocab, n_layers=2)
    model = build_model(cfg)
    tcfg = TrainConfig(total_steps=steps, lr=3e-3, warmup_steps=5,
                       checkpoint_every=10, z_loss=0.0)
    ocfg = optim.OptConfig(name="adamw", lr=3e-3, weight_decay=0.0)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8)
    state = init_state(model, ocfg, jax.random.PRNGKey(0))
    step = jax.jit(make_step_fn(model, tcfg, ocfg), donate_argnums=(0,))
    return cfg, model, tcfg, state, step, dcfg


def test_training_reduces_loss_hyft():
    """The paper's training claim (Table 2): Hyft softmax trains fine."""
    cfg, model, tcfg, state, step, dcfg = _tiny_setup("hyft16")
    state, hist = run_train(state, step, lambda s: lm_batch(dcfg, s), tcfg,
                            log_every=29, log_fn=lambda *_: None)
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.85
    assert np.isfinite(hist[-1]["loss"])


def test_hyft_training_matches_exact_softmax():
    """Loss trajectories with Hyft vs exact softmax stay close (Table 2)."""
    losses = {}
    for sm in ("exact", "hyft16"):
        cfg, model, tcfg, state, step, dcfg = _tiny_setup(sm)
        _, hist = run_train(state, step, lambda s: lm_batch(dcfg, s), tcfg,
                            log_every=29, log_fn=lambda *_: None)
        losses[sm] = hist[-1]["loss"]
    assert abs(losses["hyft16"] - losses["exact"]) < 0.25 * losses["exact"]


def test_checkpoint_restart_mid_training(tmp_path):
    """Kill at step 15, restart, final state == uninterrupted run."""
    def run(fail, ckpt_dir):
        cfg, model, tcfg, state, step, dcfg = _tiny_setup("exact", steps=20)
        calls = {"n": 0}

        def fail_at(s):
            if fail and s == 15 and calls["n"] == 0:
                calls["n"] = 1
                raise RuntimeError("injected failure")
        state, hist = run_train(state, step, lambda s: lm_batch(dcfg, s),
                                tcfg, ckpt_dir=str(ckpt_dir),
                                fail_at=fail_at, log_every=100,
                                log_fn=lambda *_: None)
        return state

    s1 = run(False, tmp_path / "a")
    s2 = run(True, tmp_path / "b")
    for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)


def test_generate_greedy():
    cfg, model, *_ = _tiny_setup("hyft16")
    from repro.configs.base import ServeConfig
    from repro.models.layers import unbox
    from repro.serve.engine import generate
    params = unbox(model.init(jax.random.PRNGKey(0)))
    batch = {"tokens": jnp.ones((2, 4), jnp.int32)}
    scfg = ServeConfig(max_len=16, cache_dtype="float32")
    out = generate(model, params, batch, scfg, max_new=5)
    assert out.shape == (2, 5)
    assert out.dtype == jnp.int32
    assert bool(jnp.all((out >= 0) & (out < cfg.vocab)))


def test_sp_decode_attention_matches_single_device():
    """The distributed Hyft L1/L2 tree == single-shard computation when the
    'tree' has one leaf (axis size 1), and stays close to unfused hyft."""
    from functools import partial
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.core.hyft import HYFT32
    from repro.distributed.compat import shard_map
    from repro.models.attention import sp_decode_attention, unfused_attention

    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, Hq, Hkv, S, D = 2, 4, 2, 64, 16
    q = jax.random.normal(ks[0], (B, Hq, 1, D), F32)
    k = jax.random.normal(ks[1], (B, Hkv, S, D), F32)
    v = jax.random.normal(ks[2], (B, Hkv, S, D), F32)
    valid = jnp.arange(S)[None, :].repeat(B, 0) < 40

    mesh = Mesh(np.array(jax.devices()[:1]), ("model",))

    @partial(shard_map, mesh=mesh,
             in_specs=(P(), P(None, None, "model"), P(None, None, "model"),
                       P(None, "model")),
             out_specs=P())
    def sp(q, k, v, valid):
        return sp_decode_attention(q, k, v, valid, HYFT32, "model")

    o_sp = sp(q, k, v, valid)
    o_ref = unfused_attention(q, k, v, "hyft32", causal=False,
                              kv_len_mask=valid)
    o_exact = unfused_attention(q, k, v, "exact", causal=False,
                                kv_len_mask=valid)
    # sp divides the PV accumulation (flash semantics); unfused divides each
    # probability -- bounded by one extra log-div Taylor application
    assert float(jnp.abs(o_sp - o_ref).max()) < 0.06
    assert float(jnp.abs(o_sp - o_exact).max()) < 0.10


@pytest.mark.parametrize("impl,max_err", [
    ("hyft16", 0.13), ("hyft32", 0.13), ("koca", 0.45), ("base2", 0.45),
    ("lut8", 0.05), ("softermax", 0.45),
])
def test_baseline_error_envelopes(impl, max_err):
    """Error ordering backing paper Table 1: hyft < koca/base2 on worst-case."""
    from repro.core.registry import get_softmax
    z = jax.random.normal(jax.random.PRNGKey(1), (64, 128), F32) * 3
    s = get_softmax(impl)(z).astype(F32)
    ref = jax.nn.softmax(z, -1)
    assert float(jnp.abs(s - ref).max()) < max_err
    assert bool(jnp.all(jnp.isfinite(s)))


def test_cost_model_reproduces_table3_ordering():
    from repro.core.costmodel import table3
    rows = {r["name"]: r for r in table3()}
    # paper: Hyft32 ~15x fewer resources than the Xilinx FP32 engine
    assert rows["hyft32"]["area_ratio_vs_fp32"] > 10
    assert rows["hyft16"]["area_ratio_vs_fp32"] > 15
    # latency improvements are large for every hybrid/fixed design
    assert rows["hyft16"]["latency_ratio_vs_fp32"] > 5
    # FOM ordering: hyft16 beats the all-FP and LUT baselines
    assert rows["hyft16"]["fom"] > rows["xilinx_fp32"]["fom"]
    assert rows["hyft16"]["fom"] > rows["fixed_lut16 [25]"]["fom"]
