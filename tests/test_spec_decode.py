"""Speculative decoding: verify kernel, drafters, greedy parity, rollback.

Contracts under test:
  * ``flash_hyft_verify`` at Sq == 1 is bitwise identical to the split-K
    decode kernels — dense AND paged, float AND fp2fx8 — and at Sq > 1
    each lane is bitwise the decode kernel's output under that lane's own
    causal frontier (the causal-within-draft mask);
  * greedy spec serving (``scheduler="spec"``) is token-for-token identical
    to vanilla greedy continuous serving across dense, fp2fx8, paged, and
    paged+prefix-cache layouts (and therefore to solo ``generate``, by the
    PR 3/4 parity suites);
  * EOS and budget act on ACCEPTED tokens only;
  * mid-spec-burst preemption under page pressure leaves PagePool
    refcounts and radix-trie-shared pages exactly consistent;
  * the n-gram drafter's proposal is always a literal continuation of its
    context (hypothesis property);
  * the top-k/top-p sampling filters (satellite) restrict draws to the
    right candidate sets.
"""
import collections

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ServeConfig

F32 = jnp.float32
I32 = jnp.int32


def _setup(arch="qwen2-1.5b", vocab=64, **kw):
    from repro.configs import get_config, smoke_config
    from repro.models import build_model
    from repro.models.layers import unbox
    cfg = smoke_config(get_config(arch)).with_(
        softmax_impl="hyft16", vocab=vocab, **kw)
    model = build_model(cfg)
    params = unbox(model.init(jax.random.PRNGKey(0)))
    return cfg, model, params


def _requests(cfg, n, rng, plen=(3, 10), max_new=(4, 10), repetitive=False):
    from repro.serve.scheduler import Request
    reqs = []
    for rid in range(n):
        if repetitive:  # motif-tiled prompt: the n-gram drafter's regime
            motif = rng.integers(0, cfg.vocab, 4).astype(np.int32)
            toks = np.concatenate(
                [np.tile(motif, 3),
                 rng.integers(0, cfg.vocab, 2).astype(np.int32)])
        else:
            toks = rng.integers(0, cfg.vocab,
                                int(rng.integers(*plen))).astype(np.int32)
        reqs.append(Request(rid=rid, tokens=toks,
                            max_new=int(rng.integers(*max_new))))
    return reqs


def _run(model, params, reqs, draft=None, **kw):
    from repro.serve.scheduler import SlotPoolEngine
    scfg = ServeConfig(max_len=kw.pop("max_len", 48),
                       cache_dtype=kw.pop("cache_dtype", "float32"),
                       n_slots=kw.pop("n_slots", 2),
                       decode_burst=4, **kw)
    eng = SlotPoolEngine(model, params, scfg, draft=draft)
    done = eng.run(list(reqs))
    return {rid: c.tokens for rid, c in done.items()}, eng


# --------------------------------------------------------------------------
# the verify kernel
# --------------------------------------------------------------------------


def _kernel_operands(rng, B=3, Hq=4, Hkv=2, Sk=40, D=16):
    q1 = jnp.asarray(rng.normal(size=(B, Hq, 1, D)), F32)
    k = jnp.asarray(rng.normal(size=(B, Hkv, Sk, D)), F32)
    v = jnp.asarray(rng.normal(size=(B, Hkv, Sk, D)), F32)
    lens = jnp.asarray([10, 25, 40])
    mask = jnp.arange(Sk)[None, :] < lens[:, None]
    return q1, k, v, mask


def test_verify_kernel_bitwise_decode_dense():
    """Sq == 1: the verify kernel IS the split-K decode kernel, bitwise."""
    from repro.core.registry import hyft_config_for
    from repro.kernels.ops import hyft_decode_attention, hyft_verify_attention
    cfg = hyft_config_for("hyft16")
    q1, k, v, mask = _kernel_operands(np.random.default_rng(0))
    dec = hyft_decode_attention(q1, k, v, cfg, kv_len_mask=mask)
    ver = hyft_verify_attention(q1, k, v, mask[:, None, :], cfg)
    assert jnp.all(dec == ver)


def test_verify_kernel_bitwise_decode_fp2fx8():
    from repro.core.registry import hyft_config_for
    from repro.kernels.ops import hyft_decode_attention, hyft_verify_attention
    from repro.models.attention import fp2fx8_quantize
    cfg = hyft_config_for("hyft16")
    q1, k, v, mask = _kernel_operands(np.random.default_rng(1))
    kr, ks = fp2fx8_quantize(k)
    vr, vs = fp2fx8_quantize(v)
    dec = hyft_decode_attention(q1, kr, vr, cfg, kv_len_mask=mask,
                                k_scale=ks, v_scale=vs)
    ver = hyft_verify_attention(q1, kr, vr, mask[:, None, :], cfg,
                                k_scale=ks, v_scale=vs)
    assert jnp.all(dec == ver)


def _paged_pool(k, v, ps):
    """Scatter contiguous (B, Hkv, Sk, D) K/V into a page pool with
    sequential per-sequence block tables."""
    B, Hkv, Sk, D = k.shape
    nb = Sk // ps
    kp = jnp.zeros((B * nb + 1, Hkv, ps, D), F32)
    vp = jnp.zeros((B * nb + 1, Hkv, ps, D), F32)
    bt = np.zeros((B, nb), np.int32)
    pid = 1
    for b in range(B):
        for j in range(nb):
            kp = kp.at[pid].set(k[b, :, j * ps:(j + 1) * ps])
            vp = vp.at[pid].set(v[b, :, j * ps:(j + 1) * ps])
            bt[b, j] = pid
            pid += 1
    return kp, vp, jnp.asarray(bt)


def test_verify_kernel_bitwise_decode_paged():
    from repro.core.registry import hyft_config_for
    from repro.kernels.ops import (hyft_paged_decode_attention,
                                   hyft_verify_attention)
    cfg = hyft_config_for("hyft16")
    q1, k, v, mask = _kernel_operands(np.random.default_rng(2))
    kp, vp, bt = _paged_pool(k, v, ps=8)
    dec = hyft_paged_decode_attention(q1, kp, vp, bt, cfg, kv_len_mask=mask)
    ver = hyft_verify_attention(q1, kp, vp, mask[:, None, :], cfg,
                                block_tables=bt)
    assert jnp.all(dec == ver)


@pytest.mark.parametrize("paged", [False, True])
def test_verify_lanes_match_decode_per_frontier(paged):
    """Every verify lane t equals the decode kernel run under lane t's own
    causal frontier (kv <= pos + t) — causal-within-draft, bitwise."""
    from repro.core.registry import hyft_config_for
    from repro.kernels.ops import (hyft_decode_attention,
                                   hyft_paged_decode_attention,
                                   hyft_verify_attention)
    cfg = hyft_config_for("hyft16")
    rng = np.random.default_rng(3)
    B, Hq, Hkv, Sk, D, S = 3, 4, 2, 40, 16, 3
    qs = jnp.asarray(rng.normal(size=(B, Hq, S, D)), F32)
    k = jnp.asarray(rng.normal(size=(B, Hkv, Sk, D)), F32)
    v = jnp.asarray(rng.normal(size=(B, Hkv, Sk, D)), F32)
    base = jnp.asarray([9, 20, 30])
    pos = base[:, None] + jnp.arange(S)[None, :]
    m3 = jnp.arange(Sk)[None, None, :] <= pos[:, :, None]
    if paged:
        kp, vp, bt = _paged_pool(k, v, ps=8)
        ver = hyft_verify_attention(qs, kp, vp, m3, cfg, block_tables=bt)
    else:
        ver = hyft_verify_attention(qs, k, v, m3, cfg)
    for t in range(S):
        mt = jnp.arange(Sk)[None, :] <= pos[:, t][:, None]
        if paged:
            dt = hyft_paged_decode_attention(qs[:, :, t:t + 1], kp, vp, bt,
                                             cfg, kv_len_mask=mt)
        else:
            dt = hyft_decode_attention(qs[:, :, t:t + 1], k, v, cfg,
                                       kv_len_mask=mt)
        assert jnp.all(dt == ver[:, :, t:t + 1])


# --------------------------------------------------------------------------
# greedy spec == vanilla greedy, across layouts
# --------------------------------------------------------------------------


def test_spec_parity_dense():
    cfg, model, params = _setup()
    reqs = _requests(cfg, 5, np.random.default_rng(0), repetitive=True)
    base, _ = _run(model, params, reqs, scheduler="continuous")
    out, eng = _run(model, params, reqs, scheduler="spec", draft_k=4)
    assert out == base
    st = eng.stats
    assert st["spec_steps"] > 0 and st["draft_tokens"] > 0
    # the repetitive prompts + a looping random model must accept SOMETHING
    assert st["accepted_tokens"] > 0


@pytest.mark.slow
@pytest.mark.parametrize("layout", ["fp2fx8", "kernel", "paged",
                                    "paged_prefix"])
def test_spec_parity_layouts(layout):
    """Token-for-token greedy parity across cache formats and layouts,
    including the fused-kernel attention path."""
    cfg, model, params = _setup()
    kw = {
        "fp2fx8": dict(cache_dtype="fp2fx8"),
        "kernel": dict(attn_mode="kernel"),
        "paged": dict(kv_layout="paged", page_size=8, attn_mode="kernel"),
        "paged_prefix": dict(kv_layout="paged", page_size=8,
                             prefix_cache=True),
    }[layout]
    reqs = _requests(cfg, 5, np.random.default_rng(1), repetitive=True)
    base, _ = _run(model, params, reqs, scheduler="continuous", **kw)
    out, _ = _run(model, params, reqs, scheduler="spec", draft_k=4, **kw)
    assert out == base


def test_spec_eos_and_budget_on_accepted_only():
    """EOS truncates emission inside the accepted prefix and frees the slot;
    budgets never overshoot — exactly the vanilla continuous behavior."""
    cfg, model, params = _setup()
    rng = np.random.default_rng(2)
    reqs = _requests(cfg, 5, rng, repetitive=True)
    base, _ = _run(model, params, reqs, scheduler="continuous")
    eos = int(collections.Counter(
        t for toks in base.values() for t in toks).most_common(1)[0][0])
    base_eos, _ = _run(model, params, reqs, scheduler="continuous",
                       eos_id=eos)
    out, _ = _run(model, params, reqs, scheduler="spec", draft_k=4,
                  eos_id=eos)
    assert out == base_eos
    for rid, toks in out.items():
        assert len(toks) <= reqs[rid].max_new
        assert eos not in toks[:-1]  # EOS only ever terminal


@pytest.mark.slow
def test_spec_model_drafter_shares_pool_full_acceptance():
    """A draft model identical to the target must have every draft accepted
    (the drafter's teacher-sync + greedy loop is bitwise the target's own
    continuation), and outputs stay parity — the strongest end-to-end check
    of the sync/draft/verify/rollback chain."""
    cfg, model, params = _setup()
    reqs = _requests(cfg, 4, np.random.default_rng(3))
    base, _ = _run(model, params, reqs, scheduler="continuous")
    out, eng = _run(model, params, reqs, scheduler="spec", draft_k=3,
                    spec_mode="model", draft=(model, params))
    assert out == base
    st = eng.stats
    assert st["draft_tokens"] > 0
    assert st["accepted_tokens"] == st["draft_tokens"]


# --------------------------------------------------------------------------
# rollback: refcounts and trie-shared pages under preemption mid-spec
# --------------------------------------------------------------------------


@pytest.mark.slow
def test_spec_preemption_rollback_refcounts_intact():
    """A page pool too small for the load forces preemption mid-spec-burst;
    afterwards every refcount must equal the trie's exact reference count
    (slots drained), outputs must equal the dense baseline, and no slot may
    retain pages — page-tail rollback never corrupts shared pages."""
    cfg, model, params = _setup()
    rng = np.random.default_rng(4)
    head = rng.integers(0, cfg.vocab, 12).astype(np.int32)
    from repro.serve.scheduler import Request
    reqs = [Request(rid=i, tokens=np.concatenate(
                [head, rng.integers(0, cfg.vocab, 3).astype(np.int32)]),
                max_new=10) for i in range(6)]
    base, _ = _run(model, params, reqs, scheduler="continuous", n_slots=3,
                   max_len=40)
    out, eng = _run(model, params, reqs, scheduler="spec", draft_k=4,
                    n_slots=3, max_len=40, kv_layout="paged", page_size=4,
                    n_pages=12, prefix_cache=True)
    assert out == base
    assert eng.stats["preemptions"] > 0, "pool was meant to be under pressure"
    assert not eng.active.any()
    assert all(not p for p in eng.slot_pages)
    # exact refcount accounting: pool refs == trie references, nothing else
    refs = eng.pool.refs
    trie_refs = collections.Counter()
    stack = [eng.trie.root]
    while stack:
        nd = stack.pop()
        stack.extend(nd.children.values())
        for p in nd.pages:
            trie_refs[p] += 1
    for p in range(1, eng.pool.n_pages + 1):
        assert refs[p] == trie_refs.get(p, 0)
    assert eng.pool.pages_in_use == eng.trie.n_pages()


def test_spec_validation():
    cfg, model, params = _setup()
    from repro.serve.scheduler import SlotPoolEngine
    with pytest.raises(ValueError, match="greedy-only"):
        SlotPoolEngine(model, params,
                       ServeConfig(scheduler="spec", temperature=0.7))
    with pytest.raises(ValueError, match="draft_k"):
        SlotPoolEngine(model, params,
                       ServeConfig(scheduler="spec", draft_k=0))
    _, ssm_model, ssm_params = _setup(arch="mamba2-370m")
    with pytest.raises(ValueError, match="attention-family"):
        SlotPoolEngine(ssm_model, ssm_params, ServeConfig(scheduler="spec"))
    with pytest.raises(ValueError, match="unknown scheduler"):
        SlotPoolEngine(model, params, ServeConfig(scheduler="warp"))


# --------------------------------------------------------------------------
# n-gram drafter
# --------------------------------------------------------------------------


def test_ngram_drafter_lookup():
    from repro.serve.spec import NgramDrafter
    d = NgramDrafter(ngram_max=3)
    # ...[5 6 7] 9 ... [5 6 7] -> continuation after the 3-gram is 9
    ctx = np.array([1, 5, 6, 7, 9, 2, 5, 6, 7], np.int32)
    assert d.draft(ctx, 2).tolist() == [9, 2]
    # recency: the MOST RECENT earlier occurrence with a full window wins
    ctx = np.array([5, 6, 1, 5, 6, 2, 5, 6], np.int32)
    assert d.draft(ctx, 1).tolist() == [2]
    # no recurrence anywhere -> empty draft
    assert d.draft(np.array([1, 2, 3, 4], np.int32), 3).size == 0
    # a tight repeat loop still yields a full draft (the occurrence whose
    # continuation is cut off by the context end is skipped for an earlier
    # full-window one) — deterministic
    ctx = np.array([3] * 8, np.int32)
    assert d.draft(ctx, 4).tolist() == d.draft(ctx, 4).tolist() == [3] * 4


def test_ngram_drafter_continuation_property():
    """Hypothesis: every draft is a literal continuation of the context —
    the drafted run appears in the context immediately after an earlier
    occurrence of the context's trailing n-gram."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st
    from repro.serve.spec import NgramDrafter

    @settings(max_examples=200, deadline=None)
    @given(ctx=st.lists(st.integers(0, 7), min_size=0, max_size=40),
           k=st.integers(0, 6), nmax=st.integers(1, 5))
    def prop(ctx, k, nmax):
        d = NgramDrafter(ngram_max=nmax)
        out = d.draft(np.array(ctx, np.int32), k)
        assert len(out) <= k
        if len(out) == 0:
            return
        ctx_a = np.array(ctx, np.int64)
        L = len(ctx_a)
        witnessed = False
        for n in range(1, min(nmax, L - 1) + 1):
            pat = ctx_a[L - n:]
            for s in range(L - n):
                if (np.array_equal(ctx_a[s:s + n], pat)
                        and np.array_equal(ctx_a[s + n:s + n + len(out)],
                                           out)):
                    witnessed = True
        assert witnessed, "draft is not a continuation of any trailing n-gram"

    prop()


# --------------------------------------------------------------------------
# sampling satellites: top-k / top-p
# --------------------------------------------------------------------------


def test_sample_top_k_restricts_support():
    from repro.serve.engine import _sample
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(4, 32)), F32)
    keys = jax.random.split(jax.random.PRNGKey(0), 64)
    draws = np.stack([np.asarray(_sample(logits, k, 1.0, 5, 1.0))
                      for k in keys])
    top5 = np.argsort(np.asarray(logits), -1)[:, -5:]
    for b in range(4):
        assert set(draws[:, b]) <= set(top5[b]), "draw outside the top-k set"
    # top_k=1 is argmax regardless of key
    g = np.asarray(jnp.argmax(logits, -1))
    for k in keys[:8]:
        assert np.array_equal(np.asarray(_sample(logits, k, 1.0, 1, 1.0)), g)


def test_sample_top_p_restricts_support():
    from repro.serve.engine import _sample
    rng = np.random.default_rng(1)
    logits = jnp.asarray(rng.normal(size=(4, 32)) * 3, F32)
    p = 0.6
    # reference nucleus: smallest prefix of the sorted probs reaching p
    probs = np.asarray(jax.nn.softmax(logits, -1))
    nuclei = []
    for b in range(4):
        order = np.argsort(-probs[b])
        cum = np.cumsum(probs[b][order])
        keep = int(np.searchsorted(cum, p)) + 1
        nuclei.append(set(order[:keep]))
    keys = jax.random.split(jax.random.PRNGKey(1), 64)
    draws = np.stack([np.asarray(_sample(logits, k, 1.0, 0, p))
                      for k in keys])
    for b in range(4):
        assert set(draws[:, b]) <= nuclei[b], "draw outside the nucleus"
    # tiny top_p degenerates to argmax (the top token is always kept)
    g = np.asarray(jnp.argmax(logits, -1))
    for k in keys[:8]:
        assert np.array_equal(np.asarray(_sample(logits, k, 1.0, 0, 1e-6)),
                              g)
    # out-of-range filters fail loudly instead of silently emitting token 0
    with pytest.raises(ValueError, match="top_p"):
        _sample(logits, keys[0], 1.0, 0, 0.0)
    with pytest.raises(ValueError, match="top_k"):
        _sample(logits, keys[0], 1.0, -3, 1.0)


def test_generate_top_k_one_is_greedy():
    """End-to-end: temperature > 0 with top_k=1 must reproduce the greedy
    decode exactly (single-candidate sampling), through the jitted loop."""
    from repro.serve.engine import generate
    cfg, model, params = _setup()
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(3), (2, 6), 0,
                                          cfg.vocab, I32)}
    greedy = generate(model, params, batch,
                      ServeConfig(max_len=32, cache_dtype="float32"),
                      max_new=6)
    topk1 = generate(model, params, batch,
                     ServeConfig(max_len=32, cache_dtype="float32",
                                 temperature=0.8, top_k=1),
                     max_new=6, key=jax.random.PRNGKey(7))
    assert jnp.all(greedy == topk1)
