"""Hypothesis property tests over the accelerator's *configuration space*.

The paper's selling point is reconfigurability (Precision, adder width,
STEP, io format).  These properties must hold for every legal HyftConfig,
not just the two presets — kernels and oracle stay bit-identical, outputs
stay valid distributions, and more bits never hurt accuracy (monotonicity
up to quantization noise).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests degrade gracefully
from hypothesis import given, settings, strategies as st

from repro.core.hyft import HyftConfig, hyft_softmax_bwd, hyft_softmax_fwd

F32 = jnp.float32


def _cfg(io, total, frac, mant, acc, step):
    return HyftConfig(io_dtype=io, total_bits=total, frac_bits=frac,
                      mant_bits=min(mant, frac), acc_bits=acc, step=step)


legal_cfgs = st.builds(
    _cfg,
    io=st.sampled_from(["float32", "float16", "bfloat16"]),
    total=st.integers(12, 28),
    frac=st.integers(6, 11),
    mant=st.integers(6, 16),
    acc=st.integers(8, 22),
    step=st.sampled_from([1, 2, 4]),
).filter(lambda c: c.frac_bits < c.total_bits)


@given(legal_cfgs, st.integers(0, 2 ** 31 - 1))
@settings(max_examples=30, deadline=None)
def test_any_config_valid_distribution(cfg, seed):
    z = jax.random.normal(jax.random.PRNGKey(seed), (4, 32), F32) * 3
    s = hyft_softmax_fwd(z, cfg).astype(F32)
    assert bool(jnp.all(jnp.isfinite(s)))
    assert float(s.min()) >= 0.0
    assert float(s.max()) <= 1.0 + 2.0 ** -6  # one output-format ulp of slack


@given(legal_cfgs, st.integers(0, 2 ** 31 - 1))
@settings(max_examples=20, deadline=None)
def test_any_config_kernel_matches_oracle(cfg, seed):
    from repro.kernels.hyft_softmax import hyft_softmax_fwd_kernel
    z = jax.random.normal(jax.random.PRNGKey(seed), (5, 48), F32) * 3
    a = hyft_softmax_fwd_kernel(z, cfg, interpret=True)
    b = hyft_softmax_fwd(z, cfg)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=15, deadline=None)
def test_more_precision_never_hurts(seed):
    """mean abs error is (weakly) monotone in Precision at fixed structure."""
    z = jax.random.normal(jax.random.PRNGKey(seed), (16, 64), F32) * 3
    ref = jax.nn.softmax(z, -1)
    errs = []
    for f in (6, 8, 10):
        cfg = HyftConfig(io_dtype="float32", total_bits=f + 8, frac_bits=f,
                         mant_bits=f, acc_bits=f + 4)
        s = hyft_softmax_fwd(z, cfg).astype(F32)
        errs.append(float(jnp.mean(jnp.abs(s - ref))))
    assert errs[0] >= errs[-1] - 1e-4  # low-bit config can't beat high-bit


@given(legal_cfgs, st.integers(0, 2 ** 31 - 1))
@settings(max_examples=20, deadline=None)
def test_any_config_bwd_finite_and_centered(cfg, seed):
    """Backward output is finite and (like the exact VJP) sums to ~0 per row
    when dy is constant: dz = s*(c - c*sum(s)) ~ s*c*(1-sum s) ~ 0."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    s = jax.nn.softmax(jax.random.normal(k1, (4, 32), F32), -1)
    dy = jnp.ones((4, 32), F32)
    dz = hyft_softmax_bwd(s, dy, cfg).astype(F32)
    assert bool(jnp.all(jnp.isfinite(dz)))
    assert float(jnp.abs(jnp.sum(dz, -1)).max()) < 0.1
