"""Sharding rules: spec generation, divisibility guards, cache specs.

Uses AbstractMesh so the production 16x16 geometry is testable on one CPU
device (no device allocation happens for spec math).
"""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED, get_config
from repro.distributed import sharding as shd
from repro.distributed.compat import abstract_mesh
from repro.models import build_model
from repro.models.layers import is_param

MESH = abstract_mesh((16, 16), ("data", "model"))
MESH3 = abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def test_spec_for_axes_basic():
    rules = shd.default_rules(MESH)
    assert shd.spec_for_axes(("embed", "mlp"), rules) == P(None, "model")
    assert shd.spec_for_axes(("batch", "seq", "embed"), rules)[0] == "data"


def test_spec_no_duplicate_mesh_axes():
    rules = dict(shd.default_rules(MESH))
    rules["embed"] = "model"  # would collide with mlp -> model
    spec = shd.spec_for_axes(("embed", "mlp"), rules)
    flat = [a for e in spec if e for a in (e if isinstance(e, tuple) else (e,))]
    assert len(flat) == len(set(flat))


@pytest.mark.parametrize("mesh", [MESH, MESH3], ids=["single", "multi"])
@pytest.mark.parametrize("name", ASSIGNED)
def test_param_shardings_divisible_all_archs(mesh, name):
    """Every parameter of every FULL-SIZE arch gets a legal sharding on the
    production meshes (the dry-run's precondition)."""
    cfg = get_config(name)
    model = build_model(cfg)
    boxed = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    rules = shd.default_rules(mesh, cfg, fsdp=True)
    psh = shd.param_shardings(mesh, boxed, rules)

    def check(p, s):
        if not is_param(p):
            return
        shape = p.value.shape
        for dim, entry in zip(shape, tuple(s.spec) + (None,) * len(shape)):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            assert dim % n == 0, (name, shape, s.spec)

    jax.tree.map(check, boxed, psh, is_leaf=is_param)


def test_cache_shardings_by_key():
    cfg = get_config("zamba2-7b")
    model = build_model(cfg)
    from repro.models.layers import unbox
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    cache = jax.eval_shape(
        lambda: model.init_cache(unbox(params), 128, 32768, jnp.bfloat16))
    rules = shd.default_rules(MESH, cfg)
    csh = shd.cache_shardings(MESH, cache, rules)
    # attention KV: seq axis -> model (sequence parallel)
    kspec = csh["shared_attn"]["k"].spec
    assert "model" in tuple(kspec)
    # ssm state: heads -> model
    sspec = csh["blocks"]["ssm"].spec
    assert "model" in tuple(sspec)


def test_divisible_drops_bad_entries():
    spec = P("model")
    out = shd._divisible(spec, (51865,), MESH)  # whisper vocab % 16 != 0
    assert tuple(out) == ()
